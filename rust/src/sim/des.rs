//! Discrete-event simulation core: a pluggable event queue (binary heap
//! or timing wheel, see [`crate::sim::sched`]) over virtual time driving
//! per-node multi-server FIFO queues, laid out from the network's
//! [`Topology`](crate::types::Topology) (any number of edge nodes).
//!
//! # Virtual-clock model
//!
//! The simulator owns a virtual clock that only moves when the next event
//! is popped from a min-heap ordered by `(time, tie class, seq)` —
//! admitted arrival joins tie-break by request id (class 0), generated
//! events by creation order (class 1) — so simultaneous events (e.g. a
//! whole synchronous round arriving at t = 0) are processed in a fixed,
//! deterministic order that is also independent of how the control plane
//! slices the trace into admits, and a trace is a pure function of its
//! inputs and seed. Wall-clock time never appears: a 10-minute saturation
//! sweep runs in milliseconds, and two runs with the same seed are
//! bit-exact (the property suite asserts this).
//!
//! # Request lifecycle (open-loop mode)
//!
//! ```text
//! arrival --(path_overhead_ms: Table 12 messages)--> [ingress link of the
//!         target's edge] --(seize; holds the link for link_queue_ms)-->
//!         [compute node] --(FIFO over the node's vCPU servers)--> depart
//! ```
//!
//! - Each edge node owns one **ingress link**: a single server that each
//!   offloaded request holds for `link_queue_ms` while being forwarded
//!   immediately. The j-th of k simultaneous uploads on one link therefore
//!   waits (j-1) slots, whose expectation (k-1)/2 x `link_queue_ms` is
//!   exactly the closed-form `Network::queueing_ms` the synchronous model
//!   charges per ingress. Local execution bypasses the links; cloud-bound
//!   requests ride their device's home-edge link
//!   ([`Topology::ingress_edge`](crate::types::Topology::ingress_edge)).
//! - **Compute nodes** (one per end device, one per edge, one cloud) are
//!   multi-server FIFO queues with the topology's per-node vCPU counts
//!   (Table 6 by default). Service demand is
//!   [`ResponseModel::single_stream_service_ms`] — the same calibrated law
//!   as the synchronous round, minus its analytic contention term, because
//!   here contention *is* the queue.
//!
//! # Synchronous-round mode
//!
//! [`sync_round_responses`] runs the same event engine in the paper's
//! §4.2.2 regime: all devices arrive at t = 0 and each request's service
//! time is its full closed-form joint response (processor-sharing
//! contention folded in analytically, infinite servers). This makes the
//! RL environment (`sim::env::Env`) a thin adapter over the DES core while
//! reproducing the seed environment's per-round outcomes exactly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::monitor::{NodeState, StateView};
use crate::sim::admission::{AdmissionPolicy, AdmitQuery, AdmitVerdict};
use crate::sim::faults::{FaultPlan, FaultTarget, RetryPolicy};
use crate::sim::latency::{ResponseModel, RoundCtx};
use crate::sim::sched::{EventQueue, SchedEvent, SchedulerKind, WheelGranularity};
use crate::sim::telemetry::{GaugeMode, Recorder, SpanKind};
use crate::sim::workload::Request;
use crate::types::{Action, Decision, ModelId, Placement, NUM_MODELS};
use crate::util::perf::PerfCounters;
use crate::util::rng::Rng;

/// One finished request with its per-component latency breakdown.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub device: usize,
    pub action: Action,
    pub arrival_ms: f64,
    /// Fixed network path overhead (control + upload messages).
    pub path_ms: f64,
    /// Wait for the target edge's ingress link (0 for local execution).
    pub link_wait_ms: f64,
    /// Wait in the compute node's FIFO before a vCPU was free.
    pub queue_ms: f64,
    /// Service time on the compute node.
    pub service_ms: f64,
    pub depart_ms: f64,
    /// depart - arrival: what the user experienced.
    pub response_ms: f64,
    /// Absolute deadline the request carried (`+inf` when none was
    /// stamped). `depart_ms <= deadline_ms` is what counts as goodput.
    pub deadline_ms: f64,
}

impl CompletedRequest {
    /// Did this response land within its deadline? (Always true for
    /// unstamped requests.)
    pub fn on_time(&self) -> bool {
        self.depart_ms <= self.deadline_ms
    }
}

/// Time-weighted backlog statistics of one compute node over a run:
/// backlog counts requests at the node (in service + waiting in its
/// FIFO); the ingress links are excluded (their waits are already
/// reported per request as `link_wait_ms`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BacklogStats {
    /// Largest instantaneous backlog the node ever held.
    pub max: usize,
    /// Time-weighted mean backlog over the run's makespan.
    pub mean: f64,
}

/// Outcome of one DES run.
#[derive(Debug, Clone, Default)]
pub struct DesOutcome {
    /// Completed requests in departure order.
    pub completed: Vec<CompletedRequest>,
    /// Virtual time of the last event (makespan).
    pub makespan_ms: f64,
    /// Arrival horizon the trace was generated for.
    pub horizon_ms: f64,
    /// Virtual times of every processed event, in processing order — the
    /// monotonicity witness the property suite checks. Collection is
    /// opt-in: [`run_open_loop`] fills it (the tests read it), while the
    /// reusable [`DesCore`] hot path leaves it empty unless
    /// [`DesCore::collect_event_times`] is set.
    pub event_times: Vec<f64>,
    /// Per-compute-node backlog statistics in DES node order (each end
    /// device, then each edge, then the cloud) — the congestion signal
    /// the drift experiment and admission control report.
    pub node_backlog: Vec<BacklogStats>,
    /// Arrivals rejected at ingress by the admission policy (they never
    /// entered the system; `completed + shed` = offered arrivals when no
    /// requests are still deferred or in flight).
    pub shed: usize,
    /// Defer events: bounded re-queues to a later control tick (one
    /// request deferred twice counts twice).
    pub deferrals: usize,
    /// Requests admitted with a degraded (cheaper) model variant.
    pub degraded: usize,
    /// Requests that terminally failed: an attempt errored out (node or
    /// link outage, or per-attempt timeout) with no retry budget left, or
    /// failover found no healthy placement. The online reward prices these
    /// like shed work; `completed + shed + failed` = offered arrivals once
    /// nothing is deferred or in flight.
    pub failed: usize,
    /// Per-attempt timeouts fired (each ends in a retry or a terminal
    /// failure; one request can time out several times).
    pub timed_out: usize,
    /// Retry re-admissions, backoff and failover alike.
    pub retries: usize,
    /// Retries that switched placement away from an unhealthy target.
    pub failovers: usize,
    /// Hot-path counters of the run's event queue (scheduled/fired
    /// events, queue work, peak depth, arena reuse). Pure observability:
    /// outcomes are bitwise identical for any counter values.
    pub perf: PerfCounters,
}

impl DesOutcome {
    /// Completed-request response times, in departure order.
    pub fn responses_ms(&self) -> Vec<f64> {
        self.completed.iter().map(|c| c.response_ms).collect()
    }

    /// Served requests per second of virtual time, over the makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.completed.is_empty() || self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / (self.makespan_ms / 1000.0)
    }

    /// Mean wait (link + compute queue) — the congestion signal the
    /// saturation sweep plots against arrival rate.
    pub fn mean_queueing_ms(&self) -> f64 {
        if self.completed.is_empty() {
            return 0.0;
        }
        self.completed.iter().map(|c| c.link_wait_ms + c.queue_ms).sum::<f64>()
            / self.completed.len() as f64
    }

    /// Largest instantaneous backlog any compute node held over the run.
    pub fn peak_backlog(&self) -> usize {
        self.node_backlog.iter().map(|b| b.max).max().unwrap_or(0)
    }

    /// Time-weighted mean backlog of the *busiest* node (the one with the
    /// largest mean) — the sustained-congestion signal, robust against
    /// dilution by the many idle devices of a large fleet.
    pub fn busiest_mean_backlog(&self) -> f64 {
        self.node_backlog.iter().map(|b| b.mean).fold(0.0, f64::max)
    }

    /// Completions that landed within their deadline (all of them when no
    /// deadlines were stamped).
    pub fn on_time_count(&self) -> usize {
        self.completed.iter().filter(|c| c.on_time()).count()
    }

    /// Completions that blew their deadline (0 when no deadlines).
    pub fn deadline_misses(&self) -> usize {
        self.completed.len() - self.on_time_count()
    }

    /// On-time completions per second of virtual time — the goodput the
    /// overload study compares admission policies on.
    ///
    /// Normalized by the arrival horizon when the run carries one
    /// (`horizon_ms > 0`): the makespan *shrinks* when a policy sheds the
    /// tail of the trace, which would inflate goodput exactly for the
    /// shedding policies the study compares. Ad-hoc outcomes without a
    /// horizon fall back to the makespan, where it equals
    /// [`DesOutcome::throughput_rps`] when no deadlines were stamped.
    pub fn goodput_rps(&self) -> f64 {
        let denom_ms = if self.horizon_ms > 0.0 { self.horizon_ms } else { self.makespan_ms };
        if denom_ms <= 0.0 {
            return 0.0;
        }
        self.on_time_count() as f64 / (denom_ms / 1000.0)
    }

    /// Fraction of resolved requests that completed:
    /// `completed / (completed + failed)` (1.0 when nothing failed —
    /// including every fault-free run). Shed requests are an admission
    /// verdict, not a failure, and do not count against availability.
    pub fn availability(&self) -> f64 {
        let resolved = self.completed.len() + self.failed;
        if resolved == 0 {
            return 1.0;
        }
        self.completed.len() as f64 / resolved as f64
    }

    /// Terminal failures per second of virtual time, horizon-normalized
    /// like [`DesOutcome::goodput_rps`] — the lost-work rate under faults.
    pub fn failed_rps(&self) -> f64 {
        let denom_ms = if self.horizon_ms > 0.0 { self.horizon_ms } else { self.makespan_ms };
        if denom_ms <= 0.0 {
            return 0.0;
        }
        self.failed as f64 / (denom_ms / 1000.0)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// Request reaches a node's queue (ingress pseudo-node or compute).
    Join { node: usize, req: usize },
    /// One hold on edge `link`'s ingress expires; it can admit the next
    /// upload.
    LinkFree { link: usize },
    /// Compute service finishes for `req` on `node`.
    Finish { node: usize, req: usize },
    /// `req`'s current attempt hits its per-attempt timeout. Only pushed
    /// under a fault plan with `timeout_ms > 0` — never on the
    /// bit-transparent identity path.
    Timeout { req: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    /// Tie class at equal times: 0 = admitted arrival joins (ordered by
    /// request id), 1 = simulator-generated events (ordered by creation
    /// counter). Keeping arrival ordering keyed on the *request id*
    /// rather than a shared push counter makes the pop order independent
    /// of how the trace was batched into admits — what pins epoch-split
    /// control-plane runs bitwise to monolithic ones even when event
    /// times tie exactly. For a monolithic run this reproduces the
    /// historical single-counter order: arrivals were always seeded
    /// first (all with lower seqs than any generated event) in trace
    /// order, which is id order.
    prio: u8,
    seq: u64,
    /// Staleness stamp, *not* part of the ordering: the owning flight's
    /// attempt generation (Join/Finish/Timeout) or the link's failure
    /// generation (LinkFree) at push time. When a failure or timeout ends
    /// an attempt it bumps the live generation, so events the dead attempt
    /// left in the heap pop as no-ops — the heap needs no removal support.
    /// Always 0 on the fault-free path.
    gen: u32,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.prio == other.prio && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest
        // (time, prio, seq) pops first. total_cmp is a total order
        // (times are never NaN).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.prio.cmp(&self.prio))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl SchedEvent for Event {
    fn time_ms(&self) -> f64 {
        self.time
    }
}

/// Multi-server FIFO queue.
struct ServerQueue {
    servers: usize,
    busy: usize,
    waiting: VecDeque<usize>,
}

impl ServerQueue {
    fn new(servers: usize) -> ServerQueue {
        assert!(servers > 0, "node with zero servers");
        ServerQueue { servers, busy: 0, waiting: VecDeque::new() }
    }
}

/// Where a live request currently sits — the location a fault boundary or
/// timeout must evict it from, with the counters that location holds.
/// Transitions mirror the event arms.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// En route to its ingress link (`enroute` + `enroute_link` counted).
    ToLink,
    /// Waiting in the ingress link's FIFO (`enroute` still counted).
    LinkQueue,
    /// En route to its compute node (`enroute` counted).
    ToNode,
    /// Waiting in the compute node's FIFO (backlog counted).
    NodeQueue,
    /// Holding a vCPU (backlog + node `busy` counted).
    InService,
    /// Departed successfully.
    Done,
    /// Terminally failed.
    Failed,
}

/// Per-request in-flight bookkeeping.
struct InFlight {
    id: u64,
    device: usize,
    action: Action,
    arrival_ms: f64,
    deadline_ms: f64,
    path_ms: f64,
    link_enq_ms: f64,
    link_wait_ms: f64,
    compute_enq_ms: f64,
    queue_ms: f64,
    service_ms: f64,
    /// Attempt generation: bumped whenever an attempt ends (completion,
    /// failure, timeout), invalidating heap events of the old attempt.
    gen: u32,
    /// Current lifecycle location (see [`Phase`]).
    phase: Phase,
    /// Retry re-admissions consumed so far.
    retries: u32,
}

/// Compute-node index for (device, placement) in the DES layout: each end
/// device, then each edge, then the cloud. Shared by the event loop and
/// the admission-prediction probe so the mapping cannot fork.
fn compute_node_index(users: usize, num_edges: usize, device: usize, p: Placement) -> usize {
    match p {
        Placement::Local => device,
        Placement::Edge(j) => users + j,
        Placement::Cloud => users + num_edges,
    }
}

/// Dense placement slot within a [`DesCore`] table row: Local, then each
/// edge, then Cloud — the same order as [`crate::types::Topology::placements`].
fn place_slot(p: Placement, num_edges: usize) -> usize {
    match p {
        Placement::Local => 0,
        Placement::Edge(j) => {
            assert!(j < num_edges, "edge {j} outside installed topology");
            1 + j
        }
        Placement::Cloud => 1 + num_edges,
    }
}

/// Inverse of [`place_slot`]: the placement a dense slot denotes. Kept
/// adjacent so the canonical order cannot fork between the two.
fn slot_place(slot: usize, num_edges: usize) -> Placement {
    match slot {
        0 => Placement::Local,
        j if j <= num_edges => Placement::Edge(j - 1),
        _ => Placement::Cloud,
    }
}

/// Push a simulator-generated event (tie class 1, creation order). `gen`
/// is the staleness stamp (see [`Event::gen`]); 0 on the fault-free path.
fn push_event(heap: &mut EventQueue<Event>, seq: &mut u64, time: f64, gen: u32, kind: EventKind) {
    *seq += 1;
    heap.push(Event { time, prio: 1, seq: *seq, gen, kind });
}

/// Reusable open-loop DES engine: memoized service tables plus the scratch
/// arena (event heap, in-flight records, per-node queues, link queues) the
/// per-call API would otherwise reallocate.
///
/// [`DesCore::install`] precomputes a dense users x models x placements
/// table of [`ResponseModel::single_stream_service_ms`] and per-device
/// path overheads for one (model, background-state) pair — the calibrated
/// response law is then pure index arithmetic inside the event loop, and
/// the same install serves any number of traces and decisions (what the
/// sweep drivers and, later, mid-trace re-decisions need). Outcomes are
/// bit-identical to the allocate-per-call [`run_open_loop`], which is now
/// a thin wrapper over a fresh core; the property suite pins both the
/// table entries (against the single-stream law) and whole-trace reuse
/// (against fresh runs).
pub struct DesCore {
    users: usize,
    num_edges: usize,
    num_places: usize,
    /// users x NUM_MODELS x num_places single-stream service times.
    svc: Vec<f64>,
    /// users x num_places fixed path overheads.
    path: Vec<f64>,
    /// Which edge-ingress link each (device, placement) traverses, encoded
    /// as 1 + link id (0 = local execution, no link).
    ingress: Vec<usize>,
    link_queue_ms: f64,
    sigma: f64,
    // --- reusable scratch ---
    heap: EventQueue<Event>,
    flights: Vec<InFlight>,
    nodes: Vec<ServerQueue>,
    links: Vec<ServerQueue>,
    // --- control-plane run state (valid between begin() and finalize()) ---
    /// Service-noise stream of the current run.
    rng: Rng,
    /// Event tie-break counter of the current run.
    seq: u64,
    /// Per-compute-node instantaneous backlog (in service + waiting).
    bl_cur: Vec<u32>,
    /// Per-compute-node peak backlog over the run.
    bl_max: Vec<u32>,
    /// Per-compute-node time-weighted backlog integral (backlog x ms).
    bl_area: Vec<f64>,
    /// Virtual time of each node's last backlog change (integral marker).
    bl_mark: Vec<f64>,
    /// Per-compute-node count of requests admitted but not yet arrived at
    /// the node's queue (their Join event is still in the heap). Feeds the
    /// admission predictor — an admission batch must see its *own* earlier
    /// admissions as committed load, not just the processed backlog.
    enroute: Vec<u32>,
    /// Per-ingress-link count of admitted offloaded requests that have not
    /// yet reached the link — the link-side companion of `enroute`, so the
    /// admission predictor can price the uplink serialization a batch of
    /// simultaneous offloads will suffer.
    enroute_link: Vec<u32>,
    /// Installed fault plan (identity by default — bit-transparent).
    plan: FaultPlan,
    /// Per-compute-node down mask of the current run (devices never
    /// fault; only edge and cloud entries can flip).
    node_down: Vec<bool>,
    /// Per-ingress-link down mask of the current run.
    link_down: Vec<bool>,
    /// Per-link failure generation: bumped on each down transition so the
    /// LinkFree events of the zeroed holds pop as no-ops.
    link_gen: Vec<u32>,
    /// Next virtual time the fault plan can change any health state
    /// (infinity under the identity plan). Advanced lazily between events
    /// — an endless flap never materializes more than one boundary.
    fault_next_ms: f64,
    /// Dedicated retry-jitter stream — never the service-noise stream, so
    /// the identity plan draws zero extra values from `rng`.
    fault_rng: Rng,
    /// Scratch buffer for collecting fault victims (borrow-friendly).
    fault_scratch: Vec<usize>,
    /// Flight-arena pushes of the current run that landed in retained
    /// capacity (no fresh allocation) — the `arena_reuse` perf counter.
    arena_reuse: u64,
    /// (user, placement) table rows recomputed since [`DesCore::begin`] —
    /// a full [`DesCore::retable`] charges the whole table, while
    /// [`DesCore::retable_delta`] charges only the dirty rows.
    retable_rows: u64,
    /// Node-state snapshot the current tables were filled from, in DES
    /// node order (devices, edges, cloud). Lets `retable_delta` diff the
    /// incoming state bitwise and skip clean rows.
    snap: Vec<NodeState>,
    /// Record per-event virtual times into `DesOutcome::event_times`
    /// (monotonicity witness). Off by default: it is test-only
    /// instrumentation that costs a push per event on the hot path.
    pub collect_event_times: bool,
    /// Optional flight recorder (off by default). Attaching one is
    /// bitwise-transparent: every hook copies scalars the engine already
    /// computed — zero extra RNG draws, no float-path changes.
    recorder: Option<Recorder>,
}

impl Default for DesCore {
    fn default() -> Self {
        DesCore::new()
    }
}

impl DesCore {
    /// An empty core; call [`DesCore::install`] before running. Uses the
    /// reference binary-heap scheduler; see [`DesCore::with_scheduler`].
    pub fn new() -> DesCore {
        DesCore::with_scheduler(SchedulerKind::Heap)
    }

    /// An empty core whose event queue uses the given scheduler. Outcomes
    /// are bitwise identical for either kind (the property suite pins
    /// this); the wheel trades the heap's O(log n) sifts for O(1)
    /// amortized calendar work on million-event traces.
    pub fn with_scheduler(sched: SchedulerKind) -> DesCore {
        DesCore {
            users: 0,
            num_edges: 0,
            num_places: 0,
            svc: Vec::new(),
            path: Vec::new(),
            ingress: Vec::new(),
            link_queue_ms: 0.0,
            sigma: 0.0,
            heap: EventQueue::new(sched),
            flights: Vec::new(),
            nodes: Vec::new(),
            links: Vec::new(),
            rng: Rng::new(0),
            seq: 0,
            bl_cur: Vec::new(),
            bl_max: Vec::new(),
            bl_area: Vec::new(),
            bl_mark: Vec::new(),
            enroute: Vec::new(),
            enroute_link: Vec::new(),
            plan: FaultPlan::none(),
            node_down: Vec::new(),
            link_down: Vec::new(),
            link_gen: Vec::new(),
            fault_next_ms: f64::INFINITY,
            fault_rng: Rng::new(0),
            fault_scratch: Vec::new(),
            arena_reuse: 0,
            retable_rows: 0,
            snap: Vec::new(),
            collect_event_times: false,
            recorder: None,
        }
    }

    /// Set the timing-wheel bucket-width policy of the underlying event
    /// queue (no-op on the heap). Pop order — and therefore every outcome
    /// — is bitwise identical for any granularity (the property suite
    /// pins auto and fixed widths against the heap).
    pub fn set_wheel_granularity(&mut self, gran: WheelGranularity) {
        self.heap.set_granularity(gran);
    }

    /// Which event scheduler this core runs on.
    pub fn scheduler(&self) -> SchedulerKind {
        self.heap.kind()
    }

    /// Precompute the service/path tables and node layout for one
    /// (response model, background state) pair. Service times and path
    /// overheads are the exact values the per-request law would produce —
    /// same function, evaluated once per (device, model, placement)
    /// instead of once per request.
    pub fn install<S: StateView>(&mut self, model: &ResponseModel, state: &S) {
        let topo = &model.net.topo;
        let users = state.users();
        assert_eq!(topo.users(), users, "topology arity vs state");
        assert_eq!(topo.num_edges(), state.num_edges(), "topology edges vs state");
        self.users = users;
        self.num_edges = topo.num_edges();
        self.num_places = topo.num_placements();
        self.fill_tables(model, state);

        // Node layout: [0, users) per-device compute, [users, users + E)
        // the edge nodes, users + E the cloud; one ingress link per edge.
        self.nodes.clear();
        self.nodes.extend(topo.devices.iter().map(|d| ServerQueue::new(d.vcpus)));
        self.nodes.extend(topo.edges.iter().map(|e| ServerQueue::new(e.vcpus)));
        self.nodes.push(ServerQueue::new(topo.cloud.vcpus));
        self.links.clear();
        self.links.extend((0..self.num_edges).map(|_| ServerQueue::new(1)));
        self.enroute.clear();
        self.enroute.resize(self.nodes.len(), 0);
        self.enroute_link.clear();
        self.enroute_link.resize(self.links.len(), 0);
    }

    /// Recompute the service/path tables for a new background state —
    /// e.g. a mid-trace [`crate::sim::drift::DriftSchedule`] cond change —
    /// **without** touching the arena, so requests in flight (and the
    /// queues they occupy) survive the swap. The topology must be the one
    /// installed; only the state (background load, monitored conds) may
    /// differ.
    pub fn retable<S: StateView>(&mut self, model: &ResponseModel, state: &S) {
        assert!(self.users > 0, "DesCore::install must precede retable");
        assert_eq!(state.users(), self.users, "retable users vs installed topology");
        assert_eq!(model.net.topo.users(), self.users, "retable topology arity");
        assert_eq!(model.net.topo.num_edges(), self.num_edges, "retable topology edges");
        assert_eq!(state.num_edges(), self.num_edges, "retable state edges");
        self.fill_tables(model, state);
    }

    /// Fill the memoized users x models x placements service table and the
    /// users x placements path/ingress tables. Path overheads charge the
    /// *state's* monitored link conditions
    /// ([`ResponseModel::path_overhead_ms`]) — bit-identical to the static
    /// table whenever the state mirrors the topology, and the lever drift
    /// scenarios move mid-trace.
    fn fill_tables<S: StateView>(&mut self, model: &ResponseModel, state: &S) {
        let topo = &model.net.topo;
        let users = self.users;
        let places = topo.placements();

        self.svc.clear();
        self.svc.reserve(users * NUM_MODELS * self.num_places);
        for device in 0..users {
            for m in 0..NUM_MODELS {
                for &p in &places {
                    self.svc.push(model.single_stream_service_ms(
                        device,
                        ModelId(m as u8),
                        p,
                        state,
                    ));
                }
            }
        }
        self.path.clear();
        self.path.reserve(users * self.num_places);
        self.ingress.clear();
        self.ingress.reserve(users * self.num_places);
        for device in 0..users {
            for &p in &places {
                self.path.push(model.path_overhead_ms(device, p, state));
                self.ingress.push(match topo.ingress_edge(device, p) {
                    None => 0,
                    Some(link) => 1 + link,
                });
            }
        }
        self.link_queue_ms = model.net.cal.link_queue_ms;
        self.sigma = model.net.cal.noise_sigma;
        self.retable_rows += (users * self.num_places) as u64;
        self.snapshot_state(state);
    }

    /// Capture the node states the tables were computed from, in DES node
    /// order (devices, edges, cloud) — the diff baseline for
    /// [`DesCore::retable_delta`].
    fn snapshot_state<S: StateView>(&mut self, state: &S) {
        self.snap.clear();
        self.snap.reserve(self.users + self.num_edges + 1);
        for d in 0..self.users {
            self.snap.push(*state.device_node(d));
        }
        for e in 0..self.num_edges {
            self.snap.push(*state.edge_node(e));
        }
        self.snap.push(*state.cloud_node());
    }

    /// Like [`DesCore::retable`], but recomputes only the (user,
    /// placement) rows whose inputs actually changed since the tables were
    /// last filled — bitwise identical to the full refill (the property
    /// suite pins this), at a fraction of the work on cond-only or
    /// single-node drift boundaries.
    ///
    /// Dirtiness follows the latency law's true dependencies:
    /// - a service cell (u, m, p) reads only the *executing* node's
    ///   cpu/mem ([`ResponseModel::single_stream_service_ms`]), so it is
    ///   dirty iff that node's load bits changed;
    /// - a path cell (u, p) reads only device u's cond and u's *home*
    ///   edge's cond ([`crate::network::Network::path_overhead_ms_with`]),
    ///   so it is dirty iff either cond changed. Ingress is pure topology
    ///   and never changes after install.
    pub fn retable_delta<S: StateView>(&mut self, model: &ResponseModel, state: &S) {
        assert!(self.users > 0, "DesCore::install must precede retable");
        assert_eq!(state.users(), self.users, "retable users vs installed topology");
        assert_eq!(model.net.topo.users(), self.users, "retable topology arity");
        assert_eq!(model.net.topo.num_edges(), self.num_edges, "retable topology edges");
        assert_eq!(state.num_edges(), self.num_edges, "retable state edges");
        let n = self.users + self.num_edges + 1;
        if self.snap.len() != n
            || self.link_queue_ms.to_bits() != model.net.cal.link_queue_ms.to_bits()
            || self.sigma.to_bits() != model.net.cal.noise_sigma.to_bits()
        {
            // No usable baseline (or the calibration itself moved): fall
            // back to the full refill.
            self.fill_tables(model, state);
            return;
        }
        let node_at = |i: usize| -> &NodeState {
            if i < self.users {
                state.device_node(i)
            } else if i < self.users + self.num_edges {
                state.edge_node(i - self.users)
            } else {
                state.cloud_node()
            }
        };
        let mut load_dirty = vec![false; n];
        let mut cond_dirty = vec![false; n];
        for i in 0..n {
            let old = &self.snap[i];
            let new = node_at(i);
            load_dirty[i] =
                old.cpu.to_bits() != new.cpu.to_bits() || old.mem.to_bits() != new.mem.to_bits();
            cond_dirty[i] = old.cond != new.cond;
        }

        let topo = &model.net.topo;
        let places = topo.placements();
        let mut rows: u64 = 0;
        for device in 0..self.users {
            let home = self.users + topo.home_edge(device);
            for (slot, &p) in places.iter().enumerate() {
                let exec = compute_node_index(self.users, self.num_edges, device, p);
                let svc_dirty = load_dirty[exec];
                let path_dirty =
                    cond_dirty[device] || (!matches!(p, Placement::Local) && cond_dirty[home]);
                if !svc_dirty && !path_dirty {
                    continue;
                }
                rows += 1;
                if svc_dirty {
                    for m in 0..NUM_MODELS {
                        self.svc[(device * NUM_MODELS + m) * self.num_places + slot] = model
                            .single_stream_service_ms(device, ModelId(m as u8), p, state);
                    }
                }
                if path_dirty {
                    self.path[device * self.num_places + slot] =
                        model.path_overhead_ms(device, p, state);
                }
            }
        }
        self.retable_rows += rows;
        self.snapshot_state(state);
    }

    /// Memoized single-stream service time for (device, model, placement)
    /// under the installed background state — bitwise equal to
    /// [`ResponseModel::single_stream_service_ms`].
    pub fn service_ms(&self, device: usize, model: ModelId, p: Placement) -> f64 {
        self.svc[(device * NUM_MODELS + model.index()) * self.num_places
            + place_slot(p, self.num_edges)]
    }

    /// Memoized fixed path overhead for (device, placement) — bitwise
    /// equal to [`crate::network::Network::path_overhead_ms`].
    pub fn path_ms(&self, device: usize, p: Placement) -> f64 {
        self.path[device * self.num_places + place_slot(p, self.num_edges)]
    }

    /// Install a fault plan for subsequent runs. [`FaultPlan::none`] — the
    /// default — keeps the engine on its bit-transparent fault-free path;
    /// edge targets must exist in the installed topology.
    pub fn set_fault_plan(&mut self, plan: &FaultPlan) {
        assert!(self.users > 0, "DesCore::install must precede set_fault_plan");
        if let Some(k) = plan.schedule.max_edge_index() {
            assert!(
                k < self.num_edges,
                "fault target edge{k} outside installed topology ({} edges)",
                self.num_edges
            );
        }
        self.plan = plan.clone();
    }

    /// Is a non-identity fault plan installed?
    pub fn faults_active(&self) -> bool {
        !self.plan.is_identity()
    }

    /// Per-compute-node down mask of the current run (DES node order:
    /// devices, edges, cloud). All-false under the identity plan; the
    /// control plane overlays it onto the live encoding so policies can
    /// route around outages.
    pub fn node_down_mask(&self) -> &[bool] {
        &self.node_down
    }

    /// Requests admitted but not yet resolved (neither departed nor
    /// terminally failed) — the in-flight term of the conservation
    /// invariant `offered == completed + shed + failed + in-flight`.
    pub fn live_count(&self) -> usize {
        self.flights
            .iter()
            .filter(|f| !matches!(f.phase, Phase::Done | Phase::Failed))
            .count()
    }

    /// The fault target a compute node maps to (devices never fault).
    fn fault_target_of_node(&self, node: usize) -> Option<FaultTarget> {
        if node < self.users {
            None
        } else if node < self.users + self.num_edges {
            Some(FaultTarget::Edge(node - self.users))
        } else {
            Some(FaultTarget::Cloud)
        }
    }

    /// Are a placement's compute node and ingress link (if any) both up?
    fn placement_healthy(&self, device: usize, p: Placement) -> bool {
        let node = compute_node_index(self.users, self.num_edges, device, p);
        if self.node_down[node] {
            return false;
        }
        match self.ingress[device * self.num_places + place_slot(p, self.num_edges)] {
            0 => true,
            link_plus_1 => !self.link_down[link_plus_1 - 1],
        }
    }

    /// Run one open-loop trace into `out`, reusing every buffer.
    ///
    /// Same contract as [`run_open_loop`] (which delegates here): the
    /// frozen `decision` routes each request, `noise_seed` drives the
    /// multiplicative log-normal service noise, and the outcome is a pure
    /// function of (installed tables, decision, trace, seed).
    /// `out.event_times` stays empty unless
    /// [`DesCore::collect_event_times`] is set.
    ///
    /// Thin composition of the control-plane primitives — one epoch
    /// spanning the whole trace: [`DesCore::begin`], one
    /// [`DesCore::admit`], [`DesCore::run_until`] infinity,
    /// [`DesCore::finalize`].
    pub fn run_open_loop_into(
        &mut self,
        decision: &Decision,
        trace: &[Request],
        horizon_ms: f64,
        noise_seed: u64,
        out: &mut DesOutcome,
    ) {
        self.begin(noise_seed, out);
        out.completed.reserve(trace.len());
        self.admit(decision, trace);
        self.run_until(f64::INFINITY, out);
        self.finalize(out);
        out.horizon_ms = horizon_ms;
    }

    /// Run one open-loop trace with the virtual clock paused every
    /// `period_ms` — a fixed-decision control loop without re-decision.
    /// This is the canonical admission-slicing convention
    /// (`Orchestrator::run_online` implements the same one, plus
    /// re-decision and drift): arrivals strictly before each tick are
    /// admitted before advancing to it, and the final epoch drains.
    /// Bitwise identical to [`DesCore::run_open_loop_into`] for any
    /// period — the pin the control-plane property tests and the
    /// `open_loop_10u_60s_12ticks` bench exercise through this one
    /// helper, so the convention cannot silently fork.
    pub fn run_sliced(
        &mut self,
        decision: &Decision,
        trace: &[Request],
        horizon_ms: f64,
        period_ms: f64,
        noise_seed: u64,
        out: &mut DesOutcome,
    ) {
        assert!(horizon_ms > 0.0, "empty horizon");
        assert!(period_ms > 0.0, "non-positive control period");
        self.begin(noise_seed, out);
        let mut t = 0.0;
        let mut i = 0usize;
        while t < horizon_ms {
            let end = if t + period_ms >= horizon_ms { horizon_ms } else { t + period_ms };
            let j = i + trace[i..].partition_point(|r| r.arrival_ms < end);
            self.admit(decision, &trace[i..j]);
            i = j;
            if end >= horizon_ms {
                self.run_until(f64::INFINITY, out);
            } else {
                self.run_until(end, out);
            }
            t = end;
        }
        self.finalize(out);
        out.horizon_ms = horizon_ms;
    }

    /// Start a run: reset the arena (retaining capacity), seed the
    /// service-noise stream, and clear `out`. The control plane calls
    /// this once per trace, then alternates [`DesCore::admit`] /
    /// [`DesCore::run_until`] per control epoch.
    pub fn begin(&mut self, noise_seed: u64, out: &mut DesOutcome) {
        assert!(self.users > 0, "DesCore::install must precede begin");
        self.heap.clear(); // also resets the queue's perf counters
        self.arena_reuse = 0;
        self.retable_rows = 0;
        self.flights.clear();
        for q in self.nodes.iter_mut() {
            q.busy = 0;
            q.waiting.clear();
        }
        for l in self.links.iter_mut() {
            l.busy = 0;
            l.waiting.clear();
        }
        self.rng = Rng::new(noise_seed);
        self.seq = 0;
        let n = self.nodes.len();
        self.bl_cur.clear();
        self.bl_cur.resize(n, 0);
        self.bl_max.clear();
        self.bl_max.resize(n, 0);
        self.bl_area.clear();
        self.bl_area.resize(n, 0.0);
        self.bl_mark.clear();
        self.bl_mark.resize(n, 0.0);
        self.enroute.clear();
        self.enroute.resize(n, 0);
        self.enroute_link.clear();
        self.enroute_link.resize(self.links.len(), 0);
        self.node_down.clear();
        self.node_down.resize(n, false);
        self.link_down.clear();
        self.link_down.resize(self.links.len(), false);
        self.link_gen.clear();
        self.link_gen.resize(self.links.len(), 0);
        self.fault_rng = Rng::new(noise_seed ^ 0xFA17_FA17);
        if self.plan.schedule.is_identity() {
            self.fault_next_ms = f64::INFINITY;
        } else {
            for node in self.users..n {
                if let Some(target) = self.fault_target_of_node(node) {
                    self.node_down[node] = self.plan.schedule.down_at(target, 0.0);
                }
            }
            let net_down = self.plan.schedule.down_at(FaultTarget::Net, 0.0);
            for l in self.link_down.iter_mut() {
                *l = net_down;
            }
            self.fault_next_ms = self.plan.schedule.next_transition_after(0.0);
        }
        out.completed.clear();
        out.event_times.clear();
        out.node_backlog.clear();
        out.makespan_ms = 0.0;
        out.horizon_ms = 0.0;
        out.shed = 0;
        out.deferrals = 0;
        out.degraded = 0;
        out.failed = 0;
        out.timed_out = 0;
        out.retries = 0;
        out.failovers = 0;
        out.perf = PerfCounters::default();
    }

    /// Admit a time-ordered batch of arrivals, each routed by `decision`
    /// (the control plane's *current* policy — requests admitted in an
    /// earlier epoch keep the action that launched them). Each arrival
    /// materializes at its queue-join time after the fixed path overhead.
    ///
    /// This is the unconditional-ingress path
    /// ([`AdmitAll`](crate::sim::admission::AdmitAll) semantics, zero
    /// per-arrival overhead); [`DesCore::admit_policed`] is the same
    /// enqueue behind a pluggable [`AdmissionPolicy`].
    pub fn admit(&mut self, decision: &Decision, arrivals: &[Request]) {
        self.check_admit_batch(decision, arrivals);
        self.flights.reserve(arrivals.len());
        for r in arrivals {
            // floor -inf: max(arrival, -inf) is bitwise the arrival, so
            // this is exactly the historical enqueue
            self.admit_request(r, decision.0[r.device], f64::NEG_INFINITY);
        }
    }

    /// Admit a time-ordered batch through an [`AdmissionPolicy`].
    ///
    /// Each arrival is judged *at its own effective arrival time*
    /// (`max(arrival, floor_ms)`): the virtual clock is advanced to that
    /// instant first, so the predicted-completion probe sees the live
    /// queues as they actually stand when the request shows up — not a
    /// snapshot frozen at the batch's control tick. Verdicts are therefore
    /// independent of how long the control period is (a whole-horizon
    /// batch judges exactly like per-tick batches); the `enroute` counters
    /// cover only genuinely simultaneous admissions. Admitted (or
    /// degraded) requests enqueue exactly as [`DesCore::admit`] would,
    /// shed ones are only counted, deferred ones are pushed onto
    /// `deferred` for the caller to re-present at its next tick (where
    /// `floor_ms` = the tick re-judges them at that instant). Counters
    /// accumulate on `out`.
    ///
    /// Policies return verdicts only — no RNG, no heap access — and the
    /// DES is event-driven, so interleaving the clock with admissions
    /// processes the identical event sequence (same pops, same noise draw
    /// order): with [`AdmitAll`] this is bit-identical to
    /// [`DesCore::admit`] + `run_until` (the property suite pins it).
    ///
    /// [`AdmitAll`]: crate::sim::admission::AdmitAll
    pub fn admit_policed(
        &mut self,
        decision: &Decision,
        arrivals: &[Request],
        floor_ms: f64,
        policy: &mut dyn AdmissionPolicy,
        deferred: &mut Vec<Request>,
        out: &mut DesOutcome,
    ) {
        self.check_admit_batch(decision, arrivals);
        for r in arrivals {
            let at = r.arrival_ms.max(floor_ms);
            // advance strictly *before* the judgment instant: events tied
            // exactly at `at` keep their heap order against this
            // arrival's own join, so AdmitAll stays bitwise batch-equal
            // even at exact ties
            self.run_before(at, out);
            let action = decision.0[r.device];
            let verdict = policy.decide(&AdmitQuery::new(self, r, action, at));
            match verdict {
                AdmitVerdict::Admit => self.admit_request(r, action, floor_ms),
                AdmitVerdict::Degrade(a) => {
                    assert_eq!(
                        a.placement, action.placement,
                        "degrade may remap the model, not the placement"
                    );
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.span(
                            at,
                            SpanKind::Degrade,
                            r.id,
                            r.device as i64,
                            -1,
                            a.model.index() as i64,
                            f64::NAN,
                        );
                    }
                    self.admit_request(r, a, floor_ms);
                    out.degraded += 1;
                }
                AdmitVerdict::Shed => {
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.span(at, SpanKind::Shed, r.id, r.device as i64, -1, -1, f64::NAN);
                    }
                    out.shed += 1;
                }
                AdmitVerdict::Defer => {
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.span(at, SpanKind::Defer, r.id, r.device as i64, -1, -1, f64::NAN);
                    }
                    deferred.push(r.clone());
                    out.deferrals += 1;
                }
            }
        }
    }

    /// Shared batch preconditions of both admit paths.
    fn check_admit_batch(&self, decision: &Decision, arrivals: &[Request]) {
        assert!(self.users > 0, "DesCore::install must precede admit");
        assert_eq!(decision.n_users(), self.users, "decision arity vs installed topology");
        assert!(
            decision.0.iter().all(|a| match a.placement {
                Placement::Edge(j) => j < self.num_edges,
                _ => true,
            }),
            "decision outside topology"
        );
        debug_assert!(
            arrivals.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
            "trace must be time-ordered"
        );
    }

    /// Enqueue one admitted request under `action`. `floor_ms` lower-bounds
    /// the effective arrival (a deferred request re-admitted at a later
    /// tick joins from that tick, not its original past); fresh arrivals
    /// always satisfy `arrival >= floor`, so the floor is bit-transparent
    /// for them.
    fn admit_request(&mut self, r: &Request, action: Action, floor_ms: f64) {
        let num_edges = self.num_edges;
        let num_places = self.num_places;
        let ingress_base = self.users + num_edges + 1;
        let pslot = place_slot(action.placement, num_edges);
        let path_ms = self.path[r.device * num_places + pslot];
        let idx = self.flights.len();
        let link_plus_1 = self.ingress[r.device * num_places + pslot];
        if self.flights.len() < self.flights.capacity() {
            // the push below lands in retained capacity: no allocation
            self.arena_reuse += 1;
        }
        self.flights.push(InFlight {
            id: r.id,
            device: r.device,
            action,
            arrival_ms: r.arrival_ms,
            deadline_ms: r.deadline_ms,
            path_ms,
            link_enq_ms: 0.0,
            link_wait_ms: 0.0,
            compute_enq_ms: 0.0,
            queue_ms: 0.0,
            service_ms: 0.0,
            gen: 0,
            phase: if link_plus_1 == 0 { Phase::ToNode } else { Phase::ToLink },
            retries: 0,
        });
        self.enroute[compute_node_index(self.users, num_edges, r.device, action.placement)] += 1;
        let target = match link_plus_1 {
            0 => r.device, // local execution: the device's own node
            link_plus_1 => {
                self.enroute_link[link_plus_1 - 1] += 1;
                ingress_base + (link_plus_1 - 1)
            }
        };
        // Arrival joins carry tie class 0 and the request id, so the
        // pop order at equal times is a property of the trace alone —
        // identical however the trace is sliced into admits. Ids must
        // therefore be unique and trace-ordered across all admits of
        // one run (the canonical `arrivals::schedule` traces are).
        self.heap.push(Event {
            time: r.arrival_ms.max(floor_ms) + path_ms,
            prio: 0,
            seq: r.id,
            gen: 0,
            kind: EventKind::Join { node: target, req: idx },
        });
        if self.plan.timeout_ms > 0.0 {
            push_event(
                &mut self.heap,
                &mut self.seq,
                r.arrival_ms.max(floor_ms) + self.plan.timeout_ms,
                0,
                EventKind::Timeout { req: idx },
            );
        }
        if let Some(rec) = self.recorder.as_mut() {
            let node = compute_node_index(self.users, num_edges, r.device, action.placement);
            rec.span(
                r.arrival_ms.max(floor_ms),
                SpanKind::Admit,
                r.id,
                r.device as i64,
                node as i64,
                action.model.index() as i64,
                f64::NAN,
            );
        }
    }

    /// Account a backlog change of compute node `node` at time `t`:
    /// integrate the old level over the elapsed interval, then shift.
    /// With an event-granularity recorder ([`GaugeMode::Event`]) this is
    /// also the gauge emission point: one sample of the affected node per
    /// backlog change, copied from the counters just updated — no RNG, no
    /// float-path change, so the mode stays bitwise-transparent.
    fn backlog_shift(&mut self, node: usize, t: f64, delta: i32) {
        self.bl_area[node] += self.bl_cur[node] as f64 * (t - self.bl_mark[node]);
        self.bl_mark[node] = t;
        let cur = (self.bl_cur[node] as i64 + delta as i64) as u32;
        self.bl_cur[node] = cur;
        if cur > self.bl_max[node] {
            self.bl_max[node] = cur;
        }
        if matches!(self.recorder.as_ref(), Some(r) if r.gauge_mode() == GaugeMode::Event) {
            let backlog = cur as usize;
            let enroute = self.enroute_count(node);
            let utilization = (backlog as f64 / self.nodes[node].servers as f64).min(1.0);
            if let Some(rec) = self.recorder.as_mut() {
                rec.gauge(t, node, backlog, enroute, utilization);
            }
        }
    }

    /// Process events up to and including virtual time `limit_ms`
    /// (infinity = drain the heap). Returning with events still pending
    /// is what lets a control plane pause the clock at a control tick,
    /// observe the live queues, swap the decision table and resume —
    /// requests in flight are untouched.
    pub fn run_until(&mut self, limit_ms: f64, out: &mut DesOutcome) {
        self.run_events::<true>(limit_ms, out)
    }

    /// Process events strictly *before* `limit_ms` — the admission
    /// interleave's bound, so events tied exactly at an arrival's
    /// judgment instant are ordered against its join by the heap
    /// comparator exactly as batch admission would.
    fn run_before(&mut self, limit_ms: f64, out: &mut DesOutcome) {
        self.run_events::<false>(limit_ms, out)
    }

    /// The event loop behind [`DesCore::run_until`] (INCLUSIVE = true)
    /// and [`DesCore::run_before`] (false); the bound test monomorphizes
    /// away.
    fn run_events<const INCLUSIVE: bool>(&mut self, limit_ms: f64, out: &mut DesOutcome) {
        let users = self.users;
        let num_edges = self.num_edges;
        let num_places = self.num_places;
        let ingress_base = users + num_edges + 1;
        let compute_node =
            |device: usize, p: Placement| compute_node_index(users, num_edges, device, p);
        let sigma = self.sigma;

        loop {
            // Fault boundaries interleave lazily with the heap: apply every
            // boundary not after the next event — or, with the heap empty,
            // up to a *finite* bound, so the control plane observes current
            // health masks at its ticks while an infinite drain skips them
            // (an endless flap would otherwise never let the run end; with
            // nothing left in flight the boundaries are unobservable).
            // One boundary per iteration, then re-peek: a failover retry
            // pushed at the boundary may pop before the old minimum.
            let next_time = self.heap.peek_time();
            let fault_due = {
                let b = self.fault_next_ms;
                let within = if INCLUSIVE { b <= limit_ms } else { b < limit_ms };
                within
                    && match next_time {
                        Some(t) => b <= t,
                        None => limit_ms.is_finite(),
                    }
            };
            if fault_due {
                self.apply_next_fault(out);
                continue;
            }
            let ev = match next_time {
                Some(t) => {
                    let past_limit = if INCLUSIVE { t > limit_ms } else { t >= limit_ms };
                    if past_limit {
                        break;
                    }
                    self.heap.pop().unwrap()
                }
                None => break,
            };
            debug_assert!(ev.time >= out.makespan_ms, "event time went backwards");
            out.makespan_ms = out.makespan_ms.max(ev.time);
            if self.collect_event_times {
                out.event_times.push(ev.time);
            }
            match ev.kind {
                EventKind::Join { node, req } if node >= ingress_base => {
                    if ev.gen != self.flights[req].gen {
                        continue; // stale: the attempt ended while en route
                    }
                    let link_id = node - ingress_base;
                    // the upload reached its link: committed -> queued
                    self.enroute_link[link_id] -= 1;
                    if self.link_down[link_id] {
                        // arriving at a dead uplink errors the attempt out
                        let (device, placement) = {
                            let f = &self.flights[req];
                            (f.device, f.action.placement)
                        };
                        self.enroute[compute_node(device, placement)] -= 1;
                        self.attempt_failed(req, ev.time, out);
                        continue;
                    }
                    self.flights[req].link_enq_ms = ev.time;
                    let link = &mut self.links[link_id];
                    if link.busy < link.servers {
                        link.busy += 1;
                        // Forwarded immediately; the hold models the edge's
                        // uplink serializing simultaneous transfers.
                        push_event(
                            &mut self.heap,
                            &mut self.seq,
                            ev.time + self.link_queue_ms,
                            self.link_gen[link_id],
                            EventKind::LinkFree { link: link_id },
                        );
                        let (device, placement, fgen) = {
                            let f = &mut self.flights[req];
                            f.phase = Phase::ToNode;
                            (f.device, f.action.placement, f.gen)
                        };
                        let target = compute_node(device, placement);
                        push_event(
                            &mut self.heap,
                            &mut self.seq,
                            ev.time,
                            fgen,
                            EventKind::Join { node: target, req },
                        );
                    } else {
                        self.flights[req].phase = Phase::LinkQueue;
                        link.waiting.push_back(req);
                    }
                }
                EventKind::LinkFree { link: link_id } => {
                    if ev.gen != self.link_gen[link_id] {
                        continue; // stale: the link went down and zeroed its holds
                    }
                    let link = &mut self.links[link_id];
                    link.busy -= 1;
                    if let Some(req) = link.waiting.pop_front() {
                        link.busy += 1;
                        self.flights[req].link_wait_ms = ev.time - self.flights[req].link_enq_ms;
                        push_event(
                            &mut self.heap,
                            &mut self.seq,
                            ev.time + self.link_queue_ms,
                            self.link_gen[link_id],
                            EventKind::LinkFree { link: link_id },
                        );
                        let (device, placement, fgen) = {
                            let f = &mut self.flights[req];
                            f.phase = Phase::ToNode;
                            (f.device, f.action.placement, f.gen)
                        };
                        let target = compute_node(device, placement);
                        push_event(
                            &mut self.heap,
                            &mut self.seq,
                            ev.time,
                            fgen,
                            EventKind::Join { node: target, req },
                        );
                    }
                }
                EventKind::Join { node, req } => {
                    if ev.gen != self.flights[req].gen {
                        continue; // stale: the attempt ended while en route
                    }
                    if self.node_down[node] {
                        // arriving at a dead compute node errors the
                        // attempt out (the link hold, if any, was spent)
                        self.enroute[node] -= 1;
                        self.attempt_failed(req, ev.time, out);
                        continue;
                    }
                    self.backlog_shift(node, ev.time, 1);
                    // the admitted request reached its compute queue: it is
                    // now part of the backlog, not the en-route count
                    self.enroute[node] -= 1;
                    self.flights[req].compute_enq_ms = ev.time;
                    let q = &mut self.nodes[node];
                    if q.busy < q.servers {
                        q.busy += 1;
                        let (device, action) = {
                            let f = &self.flights[req];
                            (f.device, f.action)
                        };
                        let mut svc = self.svc[(device * NUM_MODELS + action.model.index())
                            * num_places
                            + place_slot(action.placement, num_edges)];
                        if sigma > 0.0 {
                            svc *= (sigma * self.rng.normal()).exp();
                        }
                        self.flights[req].service_ms = svc;
                        self.flights[req].phase = Phase::InService;
                        push_event(
                            &mut self.heap,
                            &mut self.seq,
                            ev.time + svc,
                            self.flights[req].gen,
                            EventKind::Finish { node, req },
                        );
                        if let Some(rec) = self.recorder.as_mut() {
                            rec.span(
                                ev.time,
                                SpanKind::ServiceStart,
                                self.flights[req].id,
                                device as i64,
                                node as i64,
                                action.model.index() as i64,
                                f64::NAN,
                            );
                        }
                    } else {
                        self.flights[req].phase = Phase::NodeQueue;
                        q.waiting.push_back(req);
                    }
                }
                EventKind::Finish { node, req } => {
                    if ev.gen != self.flights[req].gen {
                        continue; // stale: the attempt was failed or timed out
                    }
                    self.backlog_shift(node, ev.time, -1);
                    {
                        let f = &mut self.flights[req];
                        // ending the attempt invalidates its pending Timeout
                        f.gen = f.gen.wrapping_add(1);
                        f.phase = Phase::Done;
                        f.queue_ms = ev.time - f.compute_enq_ms - f.service_ms;
                        out.completed.push(CompletedRequest {
                            id: f.id,
                            device: f.device,
                            action: f.action,
                            arrival_ms: f.arrival_ms,
                            path_ms: f.path_ms,
                            link_wait_ms: f.link_wait_ms,
                            queue_ms: f.queue_ms.max(0.0),
                            service_ms: f.service_ms,
                            depart_ms: ev.time,
                            response_ms: ev.time - f.arrival_ms,
                            deadline_ms: f.deadline_ms,
                        });
                    }
                    if self.recorder.is_some() {
                        let (id, device, model, resp) = {
                            let f = &self.flights[req];
                            (f.id, f.device, f.action.model, ev.time - f.arrival_ms)
                        };
                        if let Some(rec) = self.recorder.as_mut() {
                            rec.span(
                                ev.time,
                                SpanKind::Complete,
                                id,
                                device as i64,
                                node as i64,
                                model.index() as i64,
                                resp,
                            );
                        }
                    }
                    self.nodes[node].busy -= 1;
                    self.start_next_waiting(node, ev.time);
                }
                EventKind::Timeout { req } => {
                    if ev.gen != self.flights[req].gen {
                        continue; // the attempt already resolved
                    }
                    self.evict_for_timeout(req, ev.time, out);
                }
            }
        }
    }

    /// Seize a freed vCPU for the node's next waiting request, if any:
    /// draw its service noise, schedule its Finish, record ServiceStart.
    /// Shared by the Finish arm and the timeout-eviction path so the
    /// noise-draw order cannot fork between them.
    fn start_next_waiting(&mut self, node: usize, t: f64) {
        let num_edges = self.num_edges;
        let num_places = self.num_places;
        let sigma = self.sigma;
        let q = &mut self.nodes[node];
        if let Some(next) = q.waiting.pop_front() {
            q.busy += 1;
            let (device, action) = {
                let f = &self.flights[next];
                (f.device, f.action)
            };
            let mut svc = self.svc[(device * NUM_MODELS + action.model.index()) * num_places
                + place_slot(action.placement, num_edges)];
            if sigma > 0.0 {
                svc *= (sigma * self.rng.normal()).exp();
            }
            self.flights[next].service_ms = svc;
            self.flights[next].phase = Phase::InService;
            push_event(
                &mut self.heap,
                &mut self.seq,
                t + svc,
                self.flights[next].gen,
                EventKind::Finish { node, req: next },
            );
            if let Some(rec) = self.recorder.as_mut() {
                rec.span(
                    t,
                    SpanKind::ServiceStart,
                    self.flights[next].id,
                    device as i64,
                    node as i64,
                    action.model.index() as i64,
                    f64::NAN,
                );
            }
        }
    }

    /// Apply exactly one pending fault boundary: recompute every target's
    /// health at `fault_next_ms`, fail work on newly-down nodes/links, and
    /// advance to the next boundary. Never called under the identity plan
    /// (`fault_next_ms` stays infinite).
    fn apply_next_fault(&mut self, out: &mut DesOutcome) {
        let t = self.fault_next_ms;
        for node in self.users..self.nodes.len() {
            let target = self
                .fault_target_of_node(node)
                .expect("edge/cloud nodes map to fault targets");
            let down = self.plan.schedule.down_at(target, t);
            if down != self.node_down[node] {
                self.node_down[node] = down;
                if down {
                    self.fail_node(node, t, out);
                }
            }
        }
        let net_down = self.plan.schedule.down_at(FaultTarget::Net, t);
        for link in 0..self.links.len() {
            if net_down != self.link_down[link] {
                self.link_down[link] = net_down;
                if net_down {
                    self.fail_link(link, t, out);
                }
            }
        }
        self.fault_next_ms = self.plan.schedule.next_transition_after(t);
    }

    /// A compute node went dark at `t`: every request waiting or in
    /// service there errors out (their pending Finish events go stale via
    /// the generation bump) and the node empties. Requests en route to it
    /// error out on arrival instead.
    fn fail_node(&mut self, node: usize, t: f64, out: &mut DesOutcome) {
        let mut victims = std::mem::take(&mut self.fault_scratch);
        victims.clear();
        victims.extend(self.nodes[node].waiting.drain(..));
        for (req, f) in self.flights.iter().enumerate() {
            if f.phase == Phase::InService
                && compute_node_index(self.users, self.num_edges, f.device, f.action.placement)
                    == node
            {
                victims.push(req);
            }
        }
        self.nodes[node].busy = 0;
        for &req in &victims {
            self.backlog_shift(node, t, -1);
            self.attempt_failed(req, t, out);
        }
        self.fault_scratch = victims;
    }

    /// An ingress link went dark at `t`: in-progress holds are zeroed
    /// (their LinkFree events go stale via the link-generation bump) and
    /// queued uploads error out. Requests already forwarded past the link
    /// proceed; ones still en route to it error out on arrival.
    fn fail_link(&mut self, link: usize, t: f64, out: &mut DesOutcome) {
        self.link_gen[link] += 1;
        let mut victims = std::mem::take(&mut self.fault_scratch);
        victims.clear();
        victims.extend(self.links[link].waiting.drain(..));
        self.links[link].busy = 0;
        for &req in &victims {
            let (device, placement) = {
                let f = &self.flights[req];
                (f.device, f.action.placement)
            };
            self.enroute[compute_node_index(self.users, self.num_edges, device, placement)] -= 1;
            self.attempt_failed(req, t, out);
        }
        self.fault_scratch = victims;
    }

    /// A live attempt of `req` hit its per-attempt timeout: pull it out of
    /// wherever it sits (undoing that location's accounting), count the
    /// timeout, and hand it to the retry policy.
    fn evict_for_timeout(&mut self, req: usize, t: f64, out: &mut DesOutcome) {
        let (device, placement) = {
            let f = &self.flights[req];
            (f.device, f.action.placement)
        };
        let node = compute_node_index(self.users, self.num_edges, device, placement);
        let link = self.ingress_link(device, placement);
        match self.flights[req].phase {
            Phase::ToLink => {
                self.enroute_link[link.expect("ToLink implies an ingress link")] -= 1;
                self.enroute[node] -= 1;
            }
            Phase::ToNode => {
                self.enroute[node] -= 1;
            }
            Phase::LinkQueue => {
                let l = link.expect("LinkQueue implies an ingress link");
                let w = &mut self.links[l].waiting;
                let pos =
                    w.iter().position(|&x| x == req).expect("queued flight in link FIFO");
                w.remove(pos);
                self.enroute[node] -= 1;
            }
            Phase::NodeQueue => {
                let w = &mut self.nodes[node].waiting;
                let pos =
                    w.iter().position(|&x| x == req).expect("queued flight in node FIFO");
                w.remove(pos);
                self.backlog_shift(node, t, -1);
            }
            Phase::InService => {
                self.backlog_shift(node, t, -1);
                self.nodes[node].busy -= 1;
                self.start_next_waiting(node, t);
            }
            Phase::Done | Phase::Failed => unreachable!("stale timeouts are filtered by gen"),
        }
        out.timed_out += 1;
        if let Some(rec) = self.recorder.as_mut() {
            let f = &self.flights[req];
            rec.span(
                t,
                SpanKind::Timeout,
                f.id,
                f.device as i64,
                node as i64,
                f.action.model.index() as i64,
                f64::NAN,
            );
        }
        self.attempt_failed(req, t, out);
    }

    /// One attempt of `req` just errored out at `t` (already removed from
    /// wherever it sat): bump the generation so the old attempt's events
    /// pop stale, then let the retry policy decide — re-admit after
    /// jittered backoff (same placement, or the best healthy one under
    /// failover) or fail terminally.
    fn attempt_failed(&mut self, req: usize, t: f64, out: &mut DesOutcome) {
        out.makespan_ms = out.makespan_ms.max(t);
        self.flights[req].gen = self.flights[req].gen.wrapping_add(1);
        let used = self.flights[req].retries;
        let policy = self.plan.retry;
        if used >= policy.budget() {
            self.fail_terminally(req, t, out);
            return;
        }
        // Jitter comes from the dedicated fault stream — drawn before the
        // failover probe so delay sequences depend only on (seed, attempt).
        let jitter = self.fault_rng.f64();
        let delay = policy.backoff_delay_ms(used + 1, jitter);
        let mut failover = false;
        if matches!(policy, RetryPolicy::Failover { .. }) {
            match self.best_healthy_placement(req) {
                Some(p) => {
                    failover = p != self.flights[req].action.placement;
                    self.flights[req].action.placement = p;
                }
                None => {
                    self.fail_terminally(req, t, out);
                    return;
                }
            }
        }
        self.flights[req].retries = used + 1;
        out.retries += 1;
        if failover {
            out.failovers += 1;
        }
        if let Some(rec) = self.recorder.as_mut() {
            let f = &self.flights[req];
            let node =
                compute_node_index(self.users, self.num_edges, f.device, f.action.placement);
            rec.span(
                t,
                if failover { SpanKind::Failover } else { SpanKind::Retry },
                f.id,
                f.device as i64,
                node as i64,
                f.action.model.index() as i64,
                f64::NAN,
            );
        }
        self.readmit(req, t + delay);
    }

    /// Terminal failure: count it, mark the flight, record the span.
    fn fail_terminally(&mut self, req: usize, t: f64, out: &mut DesOutcome) {
        out.failed += 1;
        self.flights[req].phase = Phase::Failed;
        if let Some(rec) = self.recorder.as_mut() {
            let f = &self.flights[req];
            let node =
                compute_node_index(self.users, self.num_edges, f.device, f.action.placement);
            rec.span(
                t,
                SpanKind::Fail,
                f.id,
                f.device as i64,
                node as i64,
                f.action.model.index() as i64,
                t - f.arrival_ms,
            );
        }
    }

    /// The fastest (path + unloaded service, by the memoized tables)
    /// placement for `req`'s device and model whose compute node and
    /// ingress link are both currently healthy — preferring a placement
    /// *different* from the current one, keeping the current one only
    /// when it is the lone healthy option, `None` when nothing is up.
    fn best_healthy_placement(&self, req: usize) -> Option<Placement> {
        let (device, action) = {
            let f = &self.flights[req];
            (f.device, f.action)
        };
        let mut best: Option<(f64, Placement)> = None;
        for slot in 0..self.num_places {
            let p = slot_place(slot, self.num_edges);
            if p == action.placement || !self.placement_healthy(device, p) {
                continue;
            }
            let score = self.path[device * self.num_places + slot]
                + self.svc
                    [(device * NUM_MODELS + action.model.index()) * self.num_places + slot];
            if best.map_or(true, |(s, _)| score < s) {
                best = Some((score, p));
            }
        }
        match best {
            Some((_, p)) => Some(p),
            None if self.placement_healthy(device, action.placement) => Some(action.placement),
            None => None,
        }
    }

    /// Re-admit a retry at `start_ms`: reset the per-attempt fields and
    /// launch the (possibly re-placed) attempt exactly like a fresh
    /// admission — en-route counters, path delay, a fresh per-attempt
    /// timeout — under the flight's bumped generation.
    fn readmit(&mut self, req: usize, start_ms: f64) {
        let num_places = self.num_places;
        let ingress_base = self.users + self.num_edges + 1;
        let (device, placement, gen) = {
            let f = &self.flights[req];
            (f.device, f.action.placement, f.gen)
        };
        let pslot = place_slot(placement, self.num_edges);
        let path_ms = self.path[device * num_places + pslot];
        let link_plus_1 = self.ingress[device * num_places + pslot];
        {
            let f = &mut self.flights[req];
            f.path_ms = path_ms;
            f.link_enq_ms = 0.0;
            f.link_wait_ms = 0.0;
            f.compute_enq_ms = 0.0;
            f.queue_ms = 0.0;
            f.service_ms = 0.0;
            f.phase = if link_plus_1 == 0 { Phase::ToNode } else { Phase::ToLink };
        }
        self.enroute[compute_node_index(self.users, self.num_edges, device, placement)] += 1;
        let target = match link_plus_1 {
            0 => device,
            link_plus_1 => {
                self.enroute_link[link_plus_1 - 1] += 1;
                ingress_base + (link_plus_1 - 1)
            }
        };
        push_event(
            &mut self.heap,
            &mut self.seq,
            start_ms + path_ms,
            gen,
            EventKind::Join { node: target, req },
        );
        if self.plan.timeout_ms > 0.0 {
            push_event(
                &mut self.heap,
                &mut self.seq,
                start_ms + self.plan.timeout_ms,
                gen,
                EventKind::Timeout { req },
            );
        }
    }

    /// Close the run's bookkeeping: integrate every node's backlog out to
    /// the final makespan and publish the per-node statistics into
    /// `out.node_backlog`. Call once after the last
    /// [`DesCore::run_until`].
    pub fn finalize(&mut self, out: &mut DesOutcome) {
        let t = out.makespan_ms;
        out.node_backlog.clear();
        out.node_backlog.reserve(self.nodes.len());
        for i in 0..self.nodes.len() {
            let area = self.bl_area[i] + self.bl_cur[i] as f64 * (t - self.bl_mark[i]);
            let mean = if t > 0.0 { area / t } else { 0.0 };
            out.node_backlog.push(BacklogStats { max: self.bl_max[i] as usize, mean });
        }
        out.perf = self.heap.perf();
        out.perf.arena_reuse = self.arena_reuse;
        out.perf.retable_rows = self.retable_rows;
    }

    /// Number of compute nodes in the installed layout (each end device,
    /// then each edge, then the cloud — the order of
    /// [`DesOutcome::node_backlog`] and the `node` argument below).
    pub fn num_compute_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Attach (or detach) a flight recorder. `None` — the default — keeps
    /// the engine on its zero-instrumentation path; with a recorder every
    /// lifecycle hook copies already-computed scalars only, so runs stay
    /// bitwise identical either way (the property suite pins this).
    pub fn set_recorder(&mut self, recorder: Option<Recorder>) {
        self.recorder = recorder;
    }

    /// Detach the recorder; call [`Recorder::flush`] on it afterwards to
    /// drain its buffered records.
    pub fn take_recorder(&mut self) -> Option<Recorder> {
        self.recorder.take()
    }

    /// Sample every compute node's gauges (backlog, en-route count,
    /// utilization) into the recorder at virtual time `t_ms`. No-op
    /// without a recorder; the control plane calls this at its ticks.
    pub fn record_gauges(&mut self, t_ms: f64) {
        if let Some(mut rec) = self.recorder.take() {
            for node in 0..self.nodes.len() {
                rec.gauge(
                    t_ms,
                    node,
                    self.backlog(node),
                    self.enroute_count(node),
                    self.utilization(node),
                );
            }
            self.recorder = Some(rec);
        }
    }

    /// Mark a control-plane epoch boundary (the epoch index rides the
    /// span's `req` column). No-op without a recorder.
    pub fn record_epoch(&mut self, t_ms: f64, epoch: usize) {
        if let Some(rec) = self.recorder.as_mut() {
            rec.span(t_ms, SpanKind::Epoch, epoch as u64, -1, -1, -1, f64::NAN);
        }
    }

    /// Instantaneous backlog (in service + waiting) of a compute node —
    /// live mid-trace observability for the control plane.
    pub fn backlog(&self, node: usize) -> usize {
        let q = &self.nodes[node];
        q.busy + q.waiting.len()
    }

    /// Instantaneous backlog normalized by the node's parallel servers,
    /// clamped to [0, 1] — the utilization proxy the control plane merges
    /// into the monitored state at each control tick.
    pub fn utilization(&self, node: usize) -> f64 {
        let q = &self.nodes[node];
        ((q.busy + q.waiting.len()) as f64 / q.servers as f64).min(1.0)
    }

    /// Parallel servers (vCPUs) of a compute node.
    pub fn node_servers(&self, node: usize) -> usize {
        self.nodes[node].servers
    }

    /// Compute-node index a request from `device` executing at `p` runs on
    /// (the `node` argument of [`DesCore::backlog`] etc.).
    pub fn compute_node(&self, device: usize, p: Placement) -> usize {
        compute_node_index(self.users, self.num_edges, device, p)
    }

    /// Requests admitted whose Join event has not yet reached `node` —
    /// committed load the processed backlog cannot see. The admission
    /// predictor sums this with [`DesCore::backlog`] so a batch of
    /// admissions at one control tick prices its own earlier members.
    pub fn enroute_count(&self, node: usize) -> usize {
        self.enroute[node] as usize
    }

    /// Uploads committed to edge `link`'s ingress: currently holding it,
    /// waiting in its queue, or admitted but not yet arrived. Each delays
    /// a newcomer by one [`DesCore::link_hold_ms`] slot — the admission
    /// predictor's uplink-serialization estimate.
    pub fn link_load(&self, link: usize) -> usize {
        let l = &self.links[link];
        l.busy + l.waiting.len() + self.enroute_link[link] as usize
    }

    /// Which edge-ingress link a request from `device` executing at `p`
    /// traverses, if any (memoized [`crate::types::Topology::ingress_edge`]).
    pub fn ingress_link(&self, device: usize, p: Placement) -> Option<usize> {
        match self.ingress[device * self.num_places + place_slot(p, self.num_edges)] {
            0 => None,
            link_plus_1 => Some(link_plus_1 - 1),
        }
    }

    /// The per-upload serialization hold of an edge-ingress link
    /// (calibration `link_queue_ms`).
    pub fn link_hold_ms(&self) -> f64 {
        self.link_queue_ms
    }

    /// Oracle latency of `device` under the installed tables: the fastest
    /// *unloaded* full-accuracy (d0) response any placement could serve it
    /// — min over placements of path overhead + single-stream service.
    /// The `[admission] slo_multiplier` deadline is a multiple of this.
    pub fn oracle_response_ms(&self, device: usize) -> f64 {
        assert!(self.users > 0, "DesCore::install must precede oracle_response_ms");
        let d0 = crate::models::MOST_ACCURATE;
        let mut best = f64::INFINITY;
        for slot in 0..self.num_places {
            let p = slot_place(slot, self.num_edges);
            let t = self.path_ms(device, p) + self.service_ms(device, d0, p);
            best = best.min(t);
        }
        best
    }

    /// Resolve outstanding deferrals when no later control tick exists:
    /// one re-judgment at `floor_ms` (normally the horizon) against the
    /// live queues — the last chance for a drained backlog to admit them
    /// cleanly — then any straggler the policy would defer *again* is
    /// forced in, uncounted: with no tick to defer to, a "defer" verdict
    /// re-queues nothing, and re-judging at the same frozen instant until
    /// a budget burns out would only inflate the deferral counter with
    /// phantom re-queues. Shared by [`DesCore::run_admitted`] and the
    /// orchestrator's online loop so the end-of-trace drain convention
    /// cannot fork.
    pub fn drain_deferred(
        &mut self,
        decision: &Decision,
        floor_ms: f64,
        policy: &mut dyn AdmissionPolicy,
        deferred: &mut Vec<Request>,
        out: &mut DesOutcome,
    ) {
        if deferred.is_empty() {
            return;
        }
        let batch = std::mem::take(deferred);
        self.admit_policed(decision, &batch, floor_ms, policy, deferred, out);
        if !deferred.is_empty() {
            out.deferrals -= deferred.len();
            let batch = std::mem::take(deferred);
            let mut all = crate::sim::admission::AdmitAll;
            self.admit_policed(decision, &batch, floor_ms, &mut all, deferred, out);
        }
    }

    /// Run one open-loop trace through an [`AdmissionPolicy`], pausing the
    /// clock every `period_ms` like [`DesCore::run_sliced`]: arrivals
    /// strictly before each tick are judged (and admitted/shed/degraded)
    /// at the previous tick, deferred requests are re-presented at the
    /// next tick, and outstanding deferrals are resolved at the horizon
    /// before the final drain ([`DesCore::drain_deferred`]).
    ///
    /// With [`AdmitAll`](crate::sim::admission::AdmitAll) this is bitwise
    /// [`DesCore::run_sliced`] — and therefore bitwise
    /// [`DesCore::run_open_loop_into`] — which is the property-pinned
    /// default-path contract of the admission refactor.
    #[allow(clippy::too_many_arguments)]
    pub fn run_admitted(
        &mut self,
        decision: &Decision,
        trace: &[Request],
        horizon_ms: f64,
        period_ms: f64,
        policy: &mut dyn AdmissionPolicy,
        noise_seed: u64,
        out: &mut DesOutcome,
    ) {
        assert!(horizon_ms > 0.0, "empty horizon");
        assert!(period_ms > 0.0, "non-positive control period");
        self.begin(noise_seed, out);
        policy.reset();
        let mut deferred: Vec<Request> = Vec::new();
        let mut t = 0.0;
        let mut i = 0usize;
        while t < horizon_ms {
            let end = if t + period_ms >= horizon_ms { horizon_ms } else { t + period_ms };
            // re-present what the last tick deferred, then the fresh slice
            if !deferred.is_empty() {
                let batch = std::mem::take(&mut deferred);
                self.admit_policed(decision, &batch, t, policy, &mut deferred, out);
            }
            let j = i + trace[i..].partition_point(|r| r.arrival_ms < end);
            self.admit_policed(decision, &trace[i..j], t, policy, &mut deferred, out);
            i = j;
            if end >= horizon_ms {
                self.drain_deferred(decision, horizon_ms, policy, &mut deferred, out);
                self.run_until(f64::INFINITY, out);
            } else {
                self.run_until(end, out);
            }
            t = end;
        }
        self.finalize(out);
        out.horizon_ms = horizon_ms;
    }
}

/// Open-loop DES over a time-ordered arrival trace.
///
/// Each request executes the action the (frozen) `decision` assigns to its
/// device — the policy snapshot an orchestrator under evaluation installed.
/// `state` is the background-load snapshot service times are computed
/// under (any [`StateView`] whose edge count matches the model's
/// topology), and `noise_seed` drives the multiplicative log-normal
/// service noise (sigma from the calibration; pass the calibration's
/// `noise_sigma = 0` via a custom [`crate::config::Calibration`] to
/// disable it).
///
/// Convenience wrapper over a fresh [`DesCore`] (with event-time
/// collection on, for the property witnesses); callers on a hot path —
/// sweeps, repeated evaluations — should hold a [`DesCore`], install once,
/// and call [`DesCore::run_open_loop_into`] per trace instead.
pub fn run_open_loop<S: StateView>(
    model: &ResponseModel,
    state: &S,
    decision: &Decision,
    trace: &[Request],
    horizon_ms: f64,
    noise_seed: u64,
) -> DesOutcome {
    let users = state.users();
    let topo = &model.net.topo;
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(topo.users(), users, "topology arity vs state");
    assert_eq!(topo.num_edges(), state.num_edges(), "topology edges vs state");
    assert!(topo.admits(decision), "decision outside topology");

    let mut core = DesCore::new();
    core.collect_event_times = true;
    core.install(model, state);
    let mut out = DesOutcome::default();
    core.run_open_loop_into(decision, trace, horizon_ms, noise_seed, &mut out);
    out
}

/// One synchronous round (paper §4.2.2) through the event engine.
///
/// All devices arrive at t = 0; each request's service time is its full
/// closed-form joint response (`ResponseModel::device_response_ms` with
/// the round's contention context — the analytic processor-sharing
/// law), executed on infinite servers. The returned vector is indexed by
/// device and equals `ResponseModel::expected_responses` exactly, which is
/// what lets `Env` sit on the DES core without perturbing any seed
/// behavior.
pub fn sync_round_responses<S: StateView>(
    model: &ResponseModel,
    decision: &Decision,
    state: &S,
) -> Vec<f64> {
    let mut scratch = SyncScratch::new();
    let mut responses = Vec::new();
    sync_round_responses_into(model, decision, state, &mut scratch, &mut responses);
    responses
}

/// Reusable scratch for [`sync_round_responses_into`]: the event heap and
/// round-context buffers one synchronous round would otherwise allocate.
/// The RL environment holds one per instance, so the per-training-round
/// hot path (millions of `Env::step` calls per run) stops allocating.
pub struct SyncScratch {
    heap: BinaryHeap<Event>,
    ctx: RoundCtx,
}

impl Default for SyncScratch {
    fn default() -> Self {
        SyncScratch::new()
    }
}

impl SyncScratch {
    pub fn new() -> SyncScratch {
        SyncScratch {
            heap: BinaryHeap::new(),
            ctx: RoundCtx { edge_counts: Vec::new(), cloud_count: 0, ingress_counts: Vec::new() },
        }
    }
}

/// [`sync_round_responses`] writing into caller-owned buffers: `out` is
/// cleared and filled with the per-device responses (device order), and
/// `scratch` is reused across calls. Bit-identical to the allocating API.
pub fn sync_round_responses_into<S: StateView>(
    model: &ResponseModel,
    decision: &Decision,
    state: &S,
    scratch: &mut SyncScratch,
    out: &mut Vec<f64>,
) {
    let users = state.users();
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(model.net.topo.num_edges(), state.num_edges(), "topology edges vs state");
    assert!(model.net.topo.admits(decision), "decision outside topology");
    let SyncScratch { heap, ctx } = scratch;
    ctx.rebuild(&model.net.topo, decision.0.iter().map(|a| a.placement));

    heap.clear();
    for device in 0..users {
        // one tie class throughout the synchronous round: (time, seq)
        // ordering exactly as before the control-plane refactor
        heap.push(Event {
            time: 0.0,
            prio: 0,
            seq: device as u64,
            gen: 0,
            kind: EventKind::Join { node: device, req: device },
        });
    }

    out.clear();
    out.resize(users, 0.0);
    let mut seq = users as u64;
    let mut clock = 0.0f64;
    while let Some(ev) = heap.pop() {
        debug_assert!(ev.time >= clock, "event time went backwards");
        clock = clock.max(ev.time);
        match ev.kind {
            EventKind::Join { req: device, .. } => {
                let a = decision.0[device];
                let svc = model.device_response_ms(device, a.model, a.placement, ctx, state);
                seq += 1;
                heap.push(Event {
                    time: ev.time + svc,
                    prio: 0,
                    seq,
                    gen: 0,
                    kind: EventKind::Finish { node: device, req: device },
                });
            }
            EventKind::Finish { req: device, .. } => {
                out[device] = ev.time;
            }
            EventKind::LinkFree { .. } => unreachable!("no link events in a synchronous round"),
            EventKind::Timeout { .. } => unreachable!("no timeouts in a synchronous round"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Calibration, Scenario};
    use crate::monitor::{NodeState, SystemState, TopoState};
    use crate::network::Network;
    use crate::sim::arrivals::{schedule, ArrivalProcess};
    use crate::types::{ModelId, NetCond, Tier};

    fn setup(users: usize) -> (ResponseModel, SystemState) {
        let model =
            ResponseModel::new(Network::new(Scenario::exp_a(users), Calibration::default()));
        let state = SystemState {
            edge: NodeState::idle(NetCond::Regular),
            cloud: NodeState::idle(NetCond::Regular),
            devices: vec![NodeState::idle(NetCond::Regular); users],
        };
        (model, state)
    }

    fn uniform(users: usize, p: Placement, m: u8) -> Decision {
        Decision::uniform(users, Action { placement: p, model: ModelId(m) })
    }

    #[test]
    fn sync_round_equals_closed_form() {
        for users in 1..=5 {
            let (model, state) = setup(users);
            for p in Tier::ALL {
                for m in [0u8, 3, 7] {
                    let d = uniform(users, p, m);
                    let des = sync_round_responses(&model, &d, &state);
                    let closed = model.expected_responses(&d, &state);
                    assert_eq!(des, closed, "users={users} p={p:?} d{m}");
                }
            }
        }
    }

    #[test]
    fn open_loop_completes_every_request() {
        let users = 3;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 20_000.0, 5);
        let d = uniform(users, Tier::Edge(0), 7);
        let out = run_open_loop(&model, &state, &d, &trace, 20_000.0, 6);
        assert_eq!(out.completed.len(), trace.len());
        let mut ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        let mut want: Vec<u64> = trace.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want);
    }

    /// Default calibration with service noise disabled.
    fn quiet_cal() -> Calibration {
        Calibration { noise_sigma: 0.0, ..Calibration::default() }
    }

    #[test]
    fn idle_single_request_matches_service_plus_path() {
        let users = 1;
        let (_, state) = setup(users);
        let trace = vec![Request::at(0, 0, 10.0)];
        let d = uniform(users, Tier::Cloud, 0);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let out = run_open_loop(&model, &state, &d, &trace, 100.0, 1);
        let c = &out.completed[0];
        let want = model.net.path_overhead_ms(0, Tier::Cloud)
            + model.single_stream_service_ms(0, ModelId(0), Tier::Cloud, &state);
        assert!((c.response_ms - want).abs() < 1e-9, "{} vs {want}", c.response_ms);
        assert_eq!(c.link_wait_ms, 0.0);
        assert_eq!(c.queue_ms, 0.0);
    }

    #[test]
    fn simultaneous_uploads_serialize_at_the_link() {
        let users = 4;
        let (_, state) = setup(users);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let trace: Vec<Request> =
            (0..users).map(|d| Request::at(d as u64, d, 0.0)).collect();
        let d = uniform(users, Tier::Cloud, 7);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        let lq = model.net.cal.link_queue_ms;
        for (j, w) in waits.iter().enumerate() {
            assert!((w - j as f64 * lq).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn saturating_a_device_builds_queue() {
        let users = 1;
        let (model, state) = setup(users);
        // d0 local takes ~440 ms; arrivals every 100 ms pile up.
        let trace: Vec<Request> = (0..10)
            .map(|i| Request::at(i, 0, i as f64 * 100.0))
            .collect();
        let d = uniform(users, Tier::Local, 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1000.0, 3);
        assert_eq!(out.completed.len(), 10);
        assert!(out.mean_queueing_ms() > 500.0, "queue {:.0}", out.mean_queueing_ms());
        // FIFO: departures in arrival order for a single device
        let ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn event_times_monotone_and_runs_bit_exact() {
        let users = 5;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 5.0 }, users, 10_000.0, 9);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let a = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        let b = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        for w in a.event_times.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(a.responses_ms(), b.responses_ms(), "same seed must be bit-exact");
        let c = run_open_loop(&model, &state, &d, &trace, 10_000.0, 12);
        assert_ne!(a.responses_ms(), c.responses_ms(), "noise seed must matter");
    }

    #[test]
    fn edge_vcpus_bound_concurrency() {
        // 2 edge vCPUs (Table 6): 4 simultaneous edge requests run 2 at a
        // time, so two of them wait ~ one service time in the FIFO.
        let users = 4;
        let (_, state) = setup(users);
        // zero link slot isolates the compute queue
        let cal = Calibration { link_queue_ms: 0.0, ..quiet_cal() };
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), cal));
        let trace: Vec<Request> =
            (0..users).map(|d| Request::at(d as u64, d, 0.0)).collect();
        let d = uniform(users, Tier::Edge(0), 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 4);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Edge(0), &state);
        let mut queues: Vec<f64> = out.completed.iter().map(|c| c.queue_ms).collect();
        queues.sort_by(f64::total_cmp);
        assert_eq!(queues.iter().filter(|&&q| q < 1e-9).count(), 2, "{queues:?}");
        assert!((queues[2] - svc).abs() < 1e-6 && (queues[3] - svc).abs() < 1e-6);
    }

    #[test]
    fn two_edges_serialize_uploads_independently() {
        // 4 simultaneous edge uploads, split 2 + 2 across two edges: each
        // link serializes only its own pair, so the per-link waits are
        // {0, lq} instead of the single-edge {0, lq, 2lq, 3lq}.
        let users = 4;
        let cal = quiet_cal();
        let model = ResponseModel::new(Network::with_edges(Scenario::exp_a(users), cal, 2));
        let state = TopoState::idle(&model.net.topo);
        let trace: Vec<Request> =
            (0..users).map(|d| Request::at(d as u64, d, 0.0)).collect();
        let d = Decision(
            (0..users)
                .map(|i| Action { placement: Placement::Edge(i % 2), model: ModelId(7) })
                .collect(),
        );
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let lq = model.net.cal.link_queue_ms;
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        assert_eq!(out.completed.len(), users);
        for (j, w) in waits.iter().enumerate() {
            // two links, two holds each: waits 0, 0, lq, lq
            let want = if j < 2 { 0.0 } else { lq };
            assert!((w - want).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn sync_scratch_reuse_matches_alloc_api() {
        // One scratch serves rounds of different decisions, states and
        // even different user counts/topologies, bit-exactly.
        let mut scratch = SyncScratch::new();
        let mut buf = Vec::new();
        for users in 1..=4 {
            let (model, state) = setup(users);
            for m in [0u8, 3, 7] {
                for p in Tier::ALL {
                    let d = uniform(users, p, m);
                    sync_round_responses_into(&model, &d, &state, &mut scratch, &mut buf);
                    let fresh = sync_round_responses(&model, &d, &state);
                    assert_eq!(buf, fresh, "users={users} p={p:?} d{m}");
                }
            }
        }
    }

    #[test]
    fn des_core_reuse_is_bit_exact_and_isolated() {
        let users = 5;
        let (model, state) = setup(users);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let t1 = schedule(ArrivalProcess::Poisson { rate_per_s: 3.0 }, users, 8_000.0, 21);
        let t2 = schedule(
            ArrivalProcess::Mmpp {
                calm_rate_per_s: 0.5,
                burst_rate_per_s: 5.0,
                mean_phase_ms: 1500.0,
            },
            users,
            6_000.0,
            22,
        );
        let a1 = run_open_loop(&model, &state, &d, &t1, 8_000.0, 31);
        let a2 = run_open_loop(&model, &state, &d, &t2, 6_000.0, 32);

        let same = |x: &DesOutcome, y: &DesOutcome| {
            assert_eq!(x.completed.len(), y.completed.len());
            for (a, b) in x.completed.iter().zip(&y.completed) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
                assert_eq!(a.depart_ms.to_bits(), b.depart_ms.to_bits());
                assert_eq!(a.link_wait_ms.to_bits(), b.link_wait_ms.to_bits());
                assert_eq!(a.queue_ms.to_bits(), b.queue_ms.to_bits());
                assert_eq!(a.service_ms.to_bits(), b.service_ms.to_bits());
            }
            assert_eq!(x.makespan_ms.to_bits(), y.makespan_ms.to_bits());
        };

        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut out = DesOutcome::default();
        core.run_open_loop_into(&d, &t1, 8_000.0, 31, &mut out);
        same(&out, &a1);
        // event-time collection is opt-in; the hot path skips it
        assert!(out.event_times.is_empty());
        // a second, different trace through the same arena...
        core.run_open_loop_into(&d, &t2, 6_000.0, 32, &mut out);
        same(&out, &a2);
        // ...and the first again: no state leaks between runs
        core.run_open_loop_into(&d, &t1, 8_000.0, 31, &mut out);
        same(&out, &a1);
    }

    #[test]
    fn service_table_pins_single_stream_law_bitwise() {
        // The memoized tables must be the exact pre-refactor per-request
        // law — same function, evaluated once — including under busy
        // background states that exercise every multiplier.
        for edges in 1..=3usize {
            let users = 4;
            let model = ResponseModel::new(Network::with_edges(
                Scenario::exp_b(users),
                Calibration::default(),
                edges,
            ));
            let mut state = TopoState::idle(&model.net.topo);
            state.devices[0].cpu = 0.9; // busy end device
            state.devices[1].mem = 0.8; // memory pressure
            state.edges[0].cpu = 0.7; // loaded edge
            state.cloud.cpu = 0.4;
            state.cloud.mem = 0.9;
            let mut core = DesCore::new();
            core.install(&model, &state);
            for device in 0..users {
                for m in 0..8u8 {
                    for p in model.net.topo.placements() {
                        let table = core.service_ms(device, ModelId(m), p);
                        let law =
                            model.single_stream_service_ms(device, ModelId(m), p, &state);
                        assert_eq!(table.to_bits(), law.to_bits(), "svc {device}/{m}/{p:?}");
                        let path = core.path_ms(device, p);
                        let want = model.net.path_overhead_ms(device, p);
                        assert_eq!(path.to_bits(), want.to_bits(), "path {device}/{p:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn golden_edge_pair_trace_matches_component_law() {
        // Two simultaneous edge uploads, noise off: responses decompose as
        // path + service (first through the link) and path + link-slot +
        // service (second), all terms straight from the calibrated model —
        // the table-driven engine pinned to the closed-form components.
        let users = 2;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let (_, state) = setup(users);
        let trace: Vec<Request> =
            (0..users).map(|d| Request::at(d as u64, d, 0.0)).collect();
        let d = uniform(users, Tier::Edge(0), 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 7);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Edge(0), &state);
        let path = model.net.path_overhead_ms(0, Tier::Edge(0));
        let lq = model.net.cal.link_queue_ms;
        let mut got: Vec<f64> = out.completed.iter().map(|c| c.response_ms).collect();
        got.sort_by(f64::total_cmp);
        assert!((got[0] - (path + svc)).abs() < 1e-9, "{} vs {}", got[0], path + svc);
        assert!(
            (got[1] - (path + lq + svc)).abs() < 1e-9,
            "{} vs {}",
            got[1],
            path + lq + svc
        );
    }

    #[test]
    fn epoch_split_run_matches_monolithic_run() {
        // Pausing the clock at control ticks (admit per epoch + bounded
        // run_until) with an unchanged decision must reproduce the
        // monolithic run: same physics, same noise draws, same bytes.
        let users = 5;
        let (model, state) = setup(users);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let horizon = 12_000.0;
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 3.0 }, users, horizon, 41);
        let mono = run_open_loop(&model, &state, &d, &trace, horizon, 51);

        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut out = DesOutcome::default();
        core.run_sliced(&d, &trace, horizon, 2_500.0, 51, &mut out);
        assert_eq!(out.completed.len(), mono.completed.len());
        for (a, b) in out.completed.iter().zip(&mono.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
            assert_eq!(a.depart_ms.to_bits(), b.depart_ms.to_bits());
        }
        assert_eq!(out.makespan_ms.to_bits(), mono.makespan_ms.to_bits());
        // backlog stats agree too: same trajectory, differently sliced
        assert_eq!(out.node_backlog.len(), mono.node_backlog.len());
        for (a, b) in out.node_backlog.iter().zip(&mono.node_backlog) {
            assert_eq!(a.max, b.max);
            assert!((a.mean - b.mean).abs() < 1e-9, "{} vs {}", a.mean, b.mean);
        }
    }

    #[test]
    fn epoch_split_is_tie_stable_on_sync_round_traces() {
        // The adversarial case for pausable runs: a synchronized trace
        // with noise off produces *exact* event-time ties (simultaneous
        // round arrivals, constant link holds, identical services).
        // Arrival tie-breaks are keyed on request id — a property of the
        // trace, not of admission batching — so a misaligned control
        // period must still reproduce the monolithic run bitwise.
        let users = 4;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let state = TopoState::idle(&model.net.topo);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    // everyone offloads: round arrivals collide at the
                    // shared ingress link and the edge/cloud queues
                    placement: if i % 2 == 0 { Tier::Edge(0) } else { Tier::Cloud },
                    model: ModelId((i % 3) as u8),
                })
                .collect(),
        );
        let horizon = 9_000.0;
        let trace =
            schedule(ArrivalProcess::SyncRounds { period_ms: 750.0 }, users, horizon, 1);
        let mono = run_open_loop(&model, &state, &d, &trace, horizon, 5);

        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut out = DesOutcome::default();
        // period misaligned with the 750 ms rounds: ticks land mid-round
        // and on round boundaries alike
        core.run_sliced(&d, &trace, horizon, 1_000.0, 5, &mut out);
        assert_eq!(out.completed.len(), mono.completed.len());
        for (a, b) in out.completed.iter().zip(&mono.completed) {
            assert_eq!(a.id, b.id, "departure order must match under exact ties");
            assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
            assert_eq!(a.queue_ms.to_bits(), b.queue_ms.to_bits());
            assert_eq!(a.link_wait_ms.to_bits(), b.link_wait_ms.to_bits());
        }
        assert_eq!(out.makespan_ms.to_bits(), mono.makespan_ms.to_bits());
    }

    #[test]
    fn retable_swaps_service_law_without_disturbing_flights() {
        // A request in service keeps the service time it drew; a request
        // admitted after a retable executes under the new table.
        let users = 1;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let idle = TopoState::idle(&model.net.topo);
        let mut busy = idle.clone();
        busy.devices[0].cpu = 0.9; // busy-CPU factor on local compute
        let svc_idle = model.single_stream_service_ms(0, ModelId(0), Tier::Local, &idle);
        let svc_busy = model.single_stream_service_ms(0, ModelId(0), Tier::Local, &busy);
        assert!(svc_busy > svc_idle * 1.5);
        let path = model.net.path_overhead_ms(0, Tier::Local);
        let d = uniform(users, Tier::Local, 0);

        let mut core = DesCore::new();
        core.install(&model, &idle);
        let mut out = DesOutcome::default();
        core.begin(7, &mut out);
        core.admit(&d, &[Request::at(0, 0, 0.0)]);
        // pause mid-service: request 0 started under the idle table
        core.run_until(path + 1.0, &mut out);
        assert_eq!(core.backlog(0), 1, "request 0 must be in service at the pause");
        core.retable(&model, &busy);
        core.admit(&d, &[Request::at(1, 0, 2_000.0)]);
        core.run_until(f64::INFINITY, &mut out);
        core.finalize(&mut out);

        assert_eq!(out.completed.len(), 2);
        let r0 = out.completed.iter().find(|c| c.id == 0).unwrap();
        let r1 = out.completed.iter().find(|c| c.id == 1).unwrap();
        assert!((r0.service_ms - svc_idle).abs() < 1e-9, "in-flight kept idle law");
        assert!((r1.service_ms - svc_busy).abs() < 1e-9, "post-retable uses busy law");
    }

    #[test]
    fn backlog_stats_surface_congestion() {
        // The saturating single-device trace piles a queue: stats must see
        // it, and an idle run must not.
        let users = 1;
        let (model, state) = setup(users);
        let trace: Vec<Request> = (0..10)
            .map(|i| Request::at(i, 0, i as f64 * 100.0))
            .collect();
        let d = uniform(users, Tier::Local, 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1000.0, 3);
        // node 0 is the lone device; edge/cloud nodes stay empty
        assert_eq!(out.node_backlog.len(), 1 + 1 + 1);
        assert!(out.node_backlog[0].max >= 5, "{:?}", out.node_backlog);
        assert!(out.node_backlog[0].mean > 1.0, "{:?}", out.node_backlog);
        assert_eq!(out.node_backlog[1].max, 0);
        assert_eq!(out.node_backlog[2].max, 0);
        assert_eq!(out.peak_backlog(), out.node_backlog[0].max);
        assert!(out.busiest_mean_backlog() > 1.0);

        let light = vec![Request::at(0, 0, 0.0)];
        let out2 = run_open_loop(&model, &state, &d, &light, 1000.0, 3);
        assert_eq!(out2.peak_backlog(), 1);
        assert!(out2.busiest_mean_backlog() < 1.0);
    }

    #[test]
    fn run_admitted_with_admit_all_matches_pr4_engine_bitwise() {
        // The tentpole contract: the policed ingress with AdmitAll —
        // deadlines stamped and all — reproduces the pre-admission engine
        // byte for byte (same event order, same noise draw order, zero
        // extra draws), for any slicing of the trace.
        use crate::sim::admission::{stamp_deadlines, AdmitAll};
        let users = 5;
        let (model, state) = setup(users);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let horizon = 12_000.0;
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 3.0 }, users, horizon, 41);
        let mono = run_open_loop(&model, &state, &d, &trace, horizon, 51);

        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut stamped = trace.clone();
        stamp_deadlines(&mut stamped, &core, 0.0, 3.0);
        let mut out = DesOutcome::default();
        core.run_admitted(&d, &stamped, horizon, 2_500.0, &mut AdmitAll, 51, &mut out);
        assert_eq!(out.completed.len(), mono.completed.len());
        for (a, b) in out.completed.iter().zip(&mono.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
            assert_eq!(a.depart_ms.to_bits(), b.depart_ms.to_bits());
            assert_eq!(a.service_ms.to_bits(), b.service_ms.to_bits());
        }
        assert_eq!(out.makespan_ms.to_bits(), mono.makespan_ms.to_bits());
        assert_eq!((out.shed, out.deferrals, out.degraded), (0, 0, 0));
        // deadlines ride along without perturbing physics; miss accounting
        // is live
        assert!(out.completed.iter().all(|c| c.deadline_ms.is_finite()));
        assert_eq!(out.deadline_misses() + out.on_time_count(), out.completed.len());
    }

    #[test]
    fn deadline_shed_keeps_admitted_tail_inside_the_slo() {
        // Saturate one single-vCPU device 3x past capacity with noise off:
        // the prediction is exact (homogeneous per-node service), so every
        // admitted request departs within its deadline and the rest shed.
        use crate::sim::admission::{stamp_deadlines, AdmitAll, DeadlineShed};
        let users = 1;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let state = TopoState::idle(&model.net.topo);
        let d = uniform(users, Tier::Local, 0);
        let horizon = 20_000.0;
        // ~2.3 req/s capacity; offer 7 req/s
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 7.0 }, users, horizon, 9);
        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut stamped = trace.clone();
        stamp_deadlines(&mut stamped, &core, 0.0, 3.0);

        let mut shed_out = DesOutcome::default();
        core.run_admitted(&d, &stamped, horizon, 1_000.0, &mut DeadlineShed, 3, &mut shed_out);
        assert!(shed_out.shed > 0, "3x overload must shed");
        assert_eq!(shed_out.completed.len() + shed_out.shed, stamped.len());
        assert_eq!(shed_out.deadline_misses(), 0, "exact prediction: no admitted miss");

        let mut all_out = DesOutcome::default();
        core.run_admitted(&d, &stamped, horizon, 1_000.0, &mut AdmitAll, 3, &mut all_out);
        assert_eq!(all_out.completed.len(), stamped.len());
        assert!(all_out.deadline_misses() > all_out.on_time_count());
        assert!(
            shed_out.goodput_rps() > all_out.goodput_rps(),
            "shed goodput {} must beat admit-all {}",
            shed_out.goodput_rps(),
            all_out.goodput_rps()
        );
    }

    #[test]
    fn defer_requeues_to_later_ticks_and_degrade_remaps_models() {
        use crate::sim::admission::{Defer, Degrade};
        let users = 1;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let state = TopoState::idle(&model.net.topo);
        let d = uniform(users, Tier::Local, 0);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Local, &state);
        // a burst of 5 simultaneous requests, each allowed ~2.2 services
        let mut trace: Vec<Request> =
            (0..5).map(|i| Request::at(i, 0, 0.0)).collect();
        for r in trace.iter_mut() {
            r.deadline_ms = 2.2 * svc;
        }
        let mut core = DesCore::new();
        core.install(&model, &state);

        let mut defer_policy = Defer::new(2);
        let mut defer_out = DesOutcome::default();
        core.run_admitted(&d, &trace, 4.0 * svc, svc, &mut defer_policy, 1, &mut defer_out);
        // deferral never drops: everything completes, some of it deferred
        assert_eq!(defer_out.completed.len(), trace.len());
        assert_eq!(defer_out.shed, 0);
        assert!(defer_out.deferrals > 0, "burst past the deadline must defer");
        // one policy instance serves many runs identically: per-run state
        // (spent defer budgets) resets at the start of each trace
        let mut again = DesOutcome::default();
        core.run_admitted(&d, &trace, 4.0 * svc, svc, &mut defer_policy, 1, &mut again);
        assert_eq!(again.deferrals, defer_out.deferrals);
        assert_eq!(again.completed.len(), defer_out.completed.len());
        for (a, b) in again.completed.iter().zip(&defer_out.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
        }

        let mut deg_out = DesOutcome::default();
        core.run_admitted(&d, &trace, 4.0 * svc, svc, &mut Degrade, 1, &mut deg_out);
        assert_eq!(deg_out.completed.len(), trace.len());
        assert_eq!(deg_out.shed, 0);
        assert!(deg_out.degraded > 0, "burst must trigger degrades");
        assert!(
            deg_out.completed.iter().any(|c| c.action.model.index() > 0),
            "a degraded request must run a cheaper variant"
        );
        // the accuracy-time trade-off pays off: cheaper variants drain the
        // same burst sooner, so goodput-per-virtual-second comes out ahead
        assert!(deg_out.makespan_ms < defer_out.makespan_ms);
        assert!(deg_out.goodput_rps() > defer_out.goodput_rps());
    }

    #[test]
    fn multi_edge_sync_round_matches_closed_form() {
        for edges in 1..=3usize {
            let users = 6;
            let model = ResponseModel::new(Network::with_edges(
                Scenario::exp_b(users),
                Calibration::default(),
                edges,
            ));
            let state = TopoState::idle(&model.net.topo);
            let d = Decision(
                (0..users)
                    .map(|i| {
                        let placements = model.net.topo.placements();
                        Action {
                            placement: placements[i % placements.len()],
                            model: ModelId((i % 8) as u8),
                        }
                    })
                    .collect(),
            );
            let des = sync_round_responses(&model, &d, &state);
            let closed = model.expected_responses(&d, &state);
            assert_eq!(des, closed, "edges={edges}");
        }
    }
}
