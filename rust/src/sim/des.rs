//! Discrete-event simulation core: a binary-heap event queue over virtual
//! time driving per-node multi-server FIFO queues, laid out from the
//! network's [`Topology`](crate::types::Topology) (any number of edge
//! nodes).
//!
//! # Virtual-clock model
//!
//! The simulator owns a virtual clock that only moves when the next event
//! is popped from a min-heap ordered by `(time, seq)` — `seq` is a
//! monotonically increasing tie-breaker, so simultaneous events (e.g. a
//! whole synchronous round arriving at t = 0) are processed in a fixed,
//! deterministic order and a trace is a pure function of its inputs and
//! seed. Wall-clock time never appears: a 10-minute saturation sweep runs
//! in milliseconds, and two runs with the same seed are bit-exact (the
//! property suite asserts this).
//!
//! # Request lifecycle (open-loop mode)
//!
//! ```text
//! arrival --(path_overhead_ms: Table 12 messages)--> [ingress link of the
//!         target's edge] --(seize; holds the link for link_queue_ms)-->
//!         [compute node] --(FIFO over the node's vCPU servers)--> depart
//! ```
//!
//! - Each edge node owns one **ingress link**: a single server that each
//!   offloaded request holds for `link_queue_ms` while being forwarded
//!   immediately. The j-th of k simultaneous uploads on one link therefore
//!   waits (j-1) slots, whose expectation (k-1)/2 x `link_queue_ms` is
//!   exactly the closed-form `Network::queueing_ms` the synchronous model
//!   charges per ingress. Local execution bypasses the links; cloud-bound
//!   requests ride their device's home-edge link
//!   ([`Topology::ingress_edge`](crate::types::Topology::ingress_edge)).
//! - **Compute nodes** (one per end device, one per edge, one cloud) are
//!   multi-server FIFO queues with the topology's per-node vCPU counts
//!   (Table 6 by default). Service demand is
//!   [`ResponseModel::single_stream_service_ms`] — the same calibrated law
//!   as the synchronous round, minus its analytic contention term, because
//!   here contention *is* the queue.
//!
//! # Synchronous-round mode
//!
//! [`sync_round_responses`] runs the same event engine in the paper's
//! §4.2.2 regime: all devices arrive at t = 0 and each request's service
//! time is its full closed-form joint response (processor-sharing
//! contention folded in analytically, infinite servers). This makes the
//! RL environment (`sim::env::Env`) a thin adapter over the DES core while
//! reproducing the seed environment's per-round outcomes exactly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::monitor::StateView;
use crate::sim::latency::{ResponseModel, RoundCtx};
use crate::sim::workload::Request;
use crate::types::{Action, Decision, ModelId, Placement, NUM_MODELS};
use crate::util::rng::Rng;

/// One finished request with its per-component latency breakdown.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub device: usize,
    pub action: Action,
    pub arrival_ms: f64,
    /// Fixed network path overhead (control + upload messages).
    pub path_ms: f64,
    /// Wait for the target edge's ingress link (0 for local execution).
    pub link_wait_ms: f64,
    /// Wait in the compute node's FIFO before a vCPU was free.
    pub queue_ms: f64,
    /// Service time on the compute node.
    pub service_ms: f64,
    pub depart_ms: f64,
    /// depart - arrival: what the user experienced.
    pub response_ms: f64,
}

/// Outcome of one DES run.
#[derive(Debug, Clone, Default)]
pub struct DesOutcome {
    /// Completed requests in departure order.
    pub completed: Vec<CompletedRequest>,
    /// Virtual time of the last event (makespan).
    pub makespan_ms: f64,
    /// Arrival horizon the trace was generated for.
    pub horizon_ms: f64,
    /// Virtual times of every processed event, in processing order — the
    /// monotonicity witness the property suite checks. Collection is
    /// opt-in: [`run_open_loop`] fills it (the tests read it), while the
    /// reusable [`DesCore`] hot path leaves it empty unless
    /// [`DesCore::collect_event_times`] is set.
    pub event_times: Vec<f64>,
}

impl DesOutcome {
    /// Completed-request response times, in departure order.
    pub fn responses_ms(&self) -> Vec<f64> {
        self.completed.iter().map(|c| c.response_ms).collect()
    }

    /// Served requests per second of virtual time, over the makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.completed.is_empty() || self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / (self.makespan_ms / 1000.0)
    }

    /// Mean wait (link + compute queue) — the congestion signal the
    /// saturation sweep plots against arrival rate.
    pub fn mean_queueing_ms(&self) -> f64 {
        if self.completed.is_empty() {
            return 0.0;
        }
        self.completed.iter().map(|c| c.link_wait_ms + c.queue_ms).sum::<f64>()
            / self.completed.len() as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// Request reaches a node's queue (ingress pseudo-node or compute).
    Join { node: usize, req: usize },
    /// One hold on edge `link`'s ingress expires; it can admit the next
    /// upload.
    LinkFree { link: usize },
    /// Compute service finishes for `req` on `node`.
    Finish { node: usize, req: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so earliest (time, seq) pops
        // first. total_cmp is a total order (times are never NaN).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Multi-server FIFO queue.
struct ServerQueue {
    servers: usize,
    busy: usize,
    waiting: VecDeque<usize>,
}

impl ServerQueue {
    fn new(servers: usize) -> ServerQueue {
        assert!(servers > 0, "node with zero servers");
        ServerQueue { servers, busy: 0, waiting: VecDeque::new() }
    }
}

/// Per-request in-flight bookkeeping.
struct InFlight {
    id: u64,
    device: usize,
    action: Action,
    arrival_ms: f64,
    path_ms: f64,
    link_enq_ms: f64,
    link_wait_ms: f64,
    compute_enq_ms: f64,
    queue_ms: f64,
    service_ms: f64,
}

/// Dense placement slot within a [`DesCore`] table row: Local, then each
/// edge, then Cloud — the same order as [`crate::types::Topology::placements`].
fn place_slot(p: Placement, num_edges: usize) -> usize {
    match p {
        Placement::Local => 0,
        Placement::Edge(j) => {
            assert!(j < num_edges, "edge {j} outside installed topology");
            1 + j
        }
        Placement::Cloud => 1 + num_edges,
    }
}

fn push_event(heap: &mut BinaryHeap<Event>, seq: &mut u64, time: f64, kind: EventKind) {
    *seq += 1;
    heap.push(Event { time, seq: *seq, kind });
}

/// Reusable open-loop DES engine: memoized service tables plus the scratch
/// arena (event heap, in-flight records, per-node queues, link queues) the
/// per-call API would otherwise reallocate.
///
/// [`DesCore::install`] precomputes a dense users x models x placements
/// table of [`ResponseModel::single_stream_service_ms`] and per-device
/// path overheads for one (model, background-state) pair — the calibrated
/// response law is then pure index arithmetic inside the event loop, and
/// the same install serves any number of traces and decisions (what the
/// sweep drivers and, later, mid-trace re-decisions need). Outcomes are
/// bit-identical to the allocate-per-call [`run_open_loop`], which is now
/// a thin wrapper over a fresh core; the property suite pins both the
/// table entries (against the single-stream law) and whole-trace reuse
/// (against fresh runs).
pub struct DesCore {
    users: usize,
    num_edges: usize,
    num_places: usize,
    /// users x NUM_MODELS x num_places single-stream service times.
    svc: Vec<f64>,
    /// users x num_places fixed path overheads.
    path: Vec<f64>,
    /// Which edge-ingress link each (device, placement) traverses, encoded
    /// as 1 + link id (0 = local execution, no link).
    ingress: Vec<usize>,
    link_queue_ms: f64,
    sigma: f64,
    // --- reusable scratch ---
    heap: BinaryHeap<Event>,
    flights: Vec<InFlight>,
    nodes: Vec<ServerQueue>,
    links: Vec<ServerQueue>,
    /// Record per-event virtual times into `DesOutcome::event_times`
    /// (monotonicity witness). Off by default: it is test-only
    /// instrumentation that costs a push per event on the hot path.
    pub collect_event_times: bool,
}

impl Default for DesCore {
    fn default() -> Self {
        DesCore::new()
    }
}

impl DesCore {
    /// An empty core; call [`DesCore::install`] before running.
    pub fn new() -> DesCore {
        DesCore {
            users: 0,
            num_edges: 0,
            num_places: 0,
            svc: Vec::new(),
            path: Vec::new(),
            ingress: Vec::new(),
            link_queue_ms: 0.0,
            sigma: 0.0,
            heap: BinaryHeap::new(),
            flights: Vec::new(),
            nodes: Vec::new(),
            links: Vec::new(),
            collect_event_times: false,
        }
    }

    /// Precompute the service/path tables and node layout for one
    /// (response model, background state) pair. Service times and path
    /// overheads are the exact values the per-request law would produce —
    /// same function, evaluated once per (device, model, placement)
    /// instead of once per request.
    pub fn install<S: StateView>(&mut self, model: &ResponseModel, state: &S) {
        let topo = &model.net.topo;
        let users = state.users();
        assert_eq!(topo.users(), users, "topology arity vs state");
        assert_eq!(topo.num_edges(), state.num_edges(), "topology edges vs state");
        self.users = users;
        self.num_edges = topo.num_edges();
        self.num_places = topo.num_placements();
        let places = topo.placements();

        self.svc.clear();
        self.svc.reserve(users * NUM_MODELS * self.num_places);
        for device in 0..users {
            for m in 0..NUM_MODELS {
                for &p in &places {
                    self.svc.push(model.single_stream_service_ms(
                        device,
                        ModelId(m as u8),
                        p,
                        state,
                    ));
                }
            }
        }
        self.path.clear();
        self.path.reserve(users * self.num_places);
        self.ingress.clear();
        self.ingress.reserve(users * self.num_places);
        for device in 0..users {
            for &p in &places {
                self.path.push(model.net.path_overhead_ms(device, p));
                self.ingress.push(match topo.ingress_edge(device, p) {
                    None => 0,
                    Some(link) => 1 + link,
                });
            }
        }
        self.link_queue_ms = model.net.cal.link_queue_ms;
        self.sigma = model.net.cal.noise_sigma;

        // Node layout: [0, users) per-device compute, [users, users + E)
        // the edge nodes, users + E the cloud; one ingress link per edge.
        self.nodes.clear();
        self.nodes.extend(topo.devices.iter().map(|d| ServerQueue::new(d.vcpus)));
        self.nodes.extend(topo.edges.iter().map(|e| ServerQueue::new(e.vcpus)));
        self.nodes.push(ServerQueue::new(topo.cloud.vcpus));
        self.links.clear();
        self.links.extend((0..self.num_edges).map(|_| ServerQueue::new(1)));
    }

    /// Memoized single-stream service time for (device, model, placement)
    /// under the installed background state — bitwise equal to
    /// [`ResponseModel::single_stream_service_ms`].
    pub fn service_ms(&self, device: usize, model: ModelId, p: Placement) -> f64 {
        self.svc[(device * NUM_MODELS + model.index()) * self.num_places
            + place_slot(p, self.num_edges)]
    }

    /// Memoized fixed path overhead for (device, placement) — bitwise
    /// equal to [`crate::network::Network::path_overhead_ms`].
    pub fn path_ms(&self, device: usize, p: Placement) -> f64 {
        self.path[device * self.num_places + place_slot(p, self.num_edges)]
    }

    /// Run one open-loop trace into `out`, reusing every buffer.
    ///
    /// Same contract as [`run_open_loop`] (which delegates here): the
    /// frozen `decision` routes each request, `noise_seed` drives the
    /// multiplicative log-normal service noise, and the outcome is a pure
    /// function of (installed tables, decision, trace, seed).
    /// `out.event_times` stays empty unless
    /// [`DesCore::collect_event_times`] is set.
    pub fn run_open_loop_into(
        &mut self,
        decision: &Decision,
        trace: &[Request],
        horizon_ms: f64,
        noise_seed: u64,
        out: &mut DesOutcome,
    ) {
        assert!(self.users > 0, "DesCore::install must precede run_open_loop_into");
        assert_eq!(decision.n_users(), self.users, "decision arity vs installed topology");
        assert!(
            decision.0.iter().all(|a| match a.placement {
                Placement::Edge(j) => j < self.num_edges,
                _ => true,
            }),
            "decision outside topology"
        );
        debug_assert!(
            trace.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
            "trace must be time-ordered"
        );

        // Reset the arena (retains capacity from prior runs).
        self.heap.clear();
        self.flights.clear();
        self.flights.reserve(trace.len());
        for q in self.nodes.iter_mut() {
            q.busy = 0;
            q.waiting.clear();
        }
        for l in self.links.iter_mut() {
            l.busy = 0;
            l.waiting.clear();
        }
        out.completed.clear();
        out.completed.reserve(trace.len());
        out.event_times.clear();
        out.makespan_ms = 0.0;
        out.horizon_ms = horizon_ms;

        let users = self.users;
        let num_edges = self.num_edges;
        let num_places = self.num_places;
        let ingress_base = users + num_edges + 1;
        let compute_node = |device: usize, p: Placement| match p {
            Placement::Local => device,
            Placement::Edge(j) => users + j,
            Placement::Cloud => users + num_edges,
        };

        let mut rng = Rng::new(noise_seed);
        let sigma = self.sigma;
        let mut seq = 0u64;

        // Seed the heap: each arrival materializes at its queue-join time
        // after the fixed path overhead.
        for r in trace {
            let action = decision.0[r.device];
            let pslot = place_slot(action.placement, num_edges);
            let path_ms = self.path[r.device * num_places + pslot];
            let idx = self.flights.len();
            self.flights.push(InFlight {
                id: r.id,
                device: r.device,
                action,
                arrival_ms: r.arrival_ms,
                path_ms,
                link_enq_ms: 0.0,
                link_wait_ms: 0.0,
                compute_enq_ms: 0.0,
                queue_ms: 0.0,
                service_ms: 0.0,
            });
            let target = match self.ingress[r.device * num_places + pslot] {
                0 => compute_node(r.device, Placement::Local),
                link_plus_1 => ingress_base + (link_plus_1 - 1),
            };
            push_event(
                &mut self.heap,
                &mut seq,
                r.arrival_ms + path_ms,
                EventKind::Join { node: target, req: idx },
            );
        }

        while let Some(ev) = self.heap.pop() {
            debug_assert!(ev.time >= out.makespan_ms, "event time went backwards");
            out.makespan_ms = out.makespan_ms.max(ev.time);
            if self.collect_event_times {
                out.event_times.push(ev.time);
            }
            match ev.kind {
                EventKind::Join { node, req } if node >= ingress_base => {
                    let link_id = node - ingress_base;
                    self.flights[req].link_enq_ms = ev.time;
                    let link = &mut self.links[link_id];
                    if link.busy < link.servers {
                        link.busy += 1;
                        // Forwarded immediately; the hold models the edge's
                        // uplink serializing simultaneous transfers.
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time + self.link_queue_ms,
                            EventKind::LinkFree { link: link_id },
                        );
                        let (device, placement) = {
                            let f = &self.flights[req];
                            (f.device, f.action.placement)
                        };
                        let target = compute_node(device, placement);
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time,
                            EventKind::Join { node: target, req },
                        );
                    } else {
                        link.waiting.push_back(req);
                    }
                }
                EventKind::LinkFree { link: link_id } => {
                    let link = &mut self.links[link_id];
                    link.busy -= 1;
                    if let Some(req) = link.waiting.pop_front() {
                        link.busy += 1;
                        self.flights[req].link_wait_ms = ev.time - self.flights[req].link_enq_ms;
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time + self.link_queue_ms,
                            EventKind::LinkFree { link: link_id },
                        );
                        let (device, placement) = {
                            let f = &self.flights[req];
                            (f.device, f.action.placement)
                        };
                        let target = compute_node(device, placement);
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time,
                            EventKind::Join { node: target, req },
                        );
                    }
                }
                EventKind::Join { node, req } => {
                    self.flights[req].compute_enq_ms = ev.time;
                    let q = &mut self.nodes[node];
                    if q.busy < q.servers {
                        q.busy += 1;
                        let (device, action) = {
                            let f = &self.flights[req];
                            (f.device, f.action)
                        };
                        let mut svc = self.svc[(device * NUM_MODELS + action.model.index())
                            * num_places
                            + place_slot(action.placement, num_edges)];
                        if sigma > 0.0 {
                            svc *= (sigma * rng.normal()).exp();
                        }
                        self.flights[req].service_ms = svc;
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time + svc,
                            EventKind::Finish { node, req },
                        );
                    } else {
                        q.waiting.push_back(req);
                    }
                }
                EventKind::Finish { node, req } => {
                    {
                        let f = &mut self.flights[req];
                        f.queue_ms = ev.time - f.compute_enq_ms - f.service_ms;
                        out.completed.push(CompletedRequest {
                            id: f.id,
                            device: f.device,
                            action: f.action,
                            arrival_ms: f.arrival_ms,
                            path_ms: f.path_ms,
                            link_wait_ms: f.link_wait_ms,
                            queue_ms: f.queue_ms.max(0.0),
                            service_ms: f.service_ms,
                            depart_ms: ev.time,
                            response_ms: ev.time - f.arrival_ms,
                        });
                    }
                    let q = &mut self.nodes[node];
                    q.busy -= 1;
                    if let Some(next) = q.waiting.pop_front() {
                        q.busy += 1;
                        let (device, action) = {
                            let f = &self.flights[next];
                            (f.device, f.action)
                        };
                        let mut svc = self.svc[(device * NUM_MODELS + action.model.index())
                            * num_places
                            + place_slot(action.placement, num_edges)];
                        if sigma > 0.0 {
                            svc *= (sigma * rng.normal()).exp();
                        }
                        self.flights[next].service_ms = svc;
                        push_event(
                            &mut self.heap,
                            &mut seq,
                            ev.time + svc,
                            EventKind::Finish { node, req: next },
                        );
                    }
                }
            }
        }
    }
}

/// Open-loop DES over a time-ordered arrival trace.
///
/// Each request executes the action the (frozen) `decision` assigns to its
/// device — the policy snapshot an orchestrator under evaluation installed.
/// `state` is the background-load snapshot service times are computed
/// under (any [`StateView`] whose edge count matches the model's
/// topology), and `noise_seed` drives the multiplicative log-normal
/// service noise (sigma from the calibration; pass the calibration's
/// `noise_sigma = 0` via a custom [`crate::config::Calibration`] to
/// disable it).
///
/// Convenience wrapper over a fresh [`DesCore`] (with event-time
/// collection on, for the property witnesses); callers on a hot path —
/// sweeps, repeated evaluations — should hold a [`DesCore`], install once,
/// and call [`DesCore::run_open_loop_into`] per trace instead.
pub fn run_open_loop<S: StateView>(
    model: &ResponseModel,
    state: &S,
    decision: &Decision,
    trace: &[Request],
    horizon_ms: f64,
    noise_seed: u64,
) -> DesOutcome {
    let users = state.users();
    let topo = &model.net.topo;
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(topo.users(), users, "topology arity vs state");
    assert_eq!(topo.num_edges(), state.num_edges(), "topology edges vs state");
    assert!(topo.admits(decision), "decision outside topology");

    let mut core = DesCore::new();
    core.collect_event_times = true;
    core.install(model, state);
    let mut out = DesOutcome::default();
    core.run_open_loop_into(decision, trace, horizon_ms, noise_seed, &mut out);
    out
}

/// One synchronous round (paper §4.2.2) through the event engine.
///
/// All devices arrive at t = 0; each request's service time is its full
/// closed-form joint response (`ResponseModel::device_response_ms` with
/// the round's contention context — the analytic processor-sharing
/// law), executed on infinite servers. The returned vector is indexed by
/// device and equals `ResponseModel::expected_responses` exactly, which is
/// what lets `Env` sit on the DES core without perturbing any seed
/// behavior.
pub fn sync_round_responses<S: StateView>(
    model: &ResponseModel,
    decision: &Decision,
    state: &S,
) -> Vec<f64> {
    let mut scratch = SyncScratch::new();
    let mut responses = Vec::new();
    sync_round_responses_into(model, decision, state, &mut scratch, &mut responses);
    responses
}

/// Reusable scratch for [`sync_round_responses_into`]: the event heap and
/// round-context buffers one synchronous round would otherwise allocate.
/// The RL environment holds one per instance, so the per-training-round
/// hot path (millions of `Env::step` calls per run) stops allocating.
pub struct SyncScratch {
    heap: BinaryHeap<Event>,
    ctx: RoundCtx,
}

impl Default for SyncScratch {
    fn default() -> Self {
        SyncScratch::new()
    }
}

impl SyncScratch {
    pub fn new() -> SyncScratch {
        SyncScratch {
            heap: BinaryHeap::new(),
            ctx: RoundCtx { edge_counts: Vec::new(), cloud_count: 0, ingress_counts: Vec::new() },
        }
    }
}

/// [`sync_round_responses`] writing into caller-owned buffers: `out` is
/// cleared and filled with the per-device responses (device order), and
/// `scratch` is reused across calls. Bit-identical to the allocating API.
pub fn sync_round_responses_into<S: StateView>(
    model: &ResponseModel,
    decision: &Decision,
    state: &S,
    scratch: &mut SyncScratch,
    out: &mut Vec<f64>,
) {
    let users = state.users();
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(model.net.topo.num_edges(), state.num_edges(), "topology edges vs state");
    assert!(model.net.topo.admits(decision), "decision outside topology");
    let SyncScratch { heap, ctx } = scratch;
    ctx.rebuild(&model.net.topo, decision.0.iter().map(|a| a.placement));

    heap.clear();
    for device in 0..users {
        heap.push(Event {
            time: 0.0,
            seq: device as u64,
            kind: EventKind::Join { node: device, req: device },
        });
    }

    out.clear();
    out.resize(users, 0.0);
    let mut seq = users as u64;
    let mut clock = 0.0f64;
    while let Some(ev) = heap.pop() {
        debug_assert!(ev.time >= clock, "event time went backwards");
        clock = clock.max(ev.time);
        match ev.kind {
            EventKind::Join { req: device, .. } => {
                let a = decision.0[device];
                let svc = model.device_response_ms(device, a.model, a.placement, ctx, state);
                seq += 1;
                heap.push(Event {
                    time: ev.time + svc,
                    seq,
                    kind: EventKind::Finish { node: device, req: device },
                });
            }
            EventKind::Finish { req: device, .. } => {
                out[device] = ev.time;
            }
            EventKind::LinkFree { .. } => unreachable!("no link events in a synchronous round"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Calibration, Scenario};
    use crate::monitor::{NodeState, SystemState, TopoState};
    use crate::network::Network;
    use crate::sim::arrivals::{schedule, ArrivalProcess};
    use crate::types::{ModelId, NetCond, Tier};

    fn setup(users: usize) -> (ResponseModel, SystemState) {
        let model =
            ResponseModel::new(Network::new(Scenario::exp_a(users), Calibration::default()));
        let state = SystemState {
            edge: NodeState::idle(NetCond::Regular),
            cloud: NodeState::idle(NetCond::Regular),
            devices: vec![NodeState::idle(NetCond::Regular); users],
        };
        (model, state)
    }

    fn uniform(users: usize, p: Placement, m: u8) -> Decision {
        Decision::uniform(users, Action { placement: p, model: ModelId(m) })
    }

    #[test]
    fn sync_round_equals_closed_form() {
        for users in 1..=5 {
            let (model, state) = setup(users);
            for p in Tier::ALL {
                for m in [0u8, 3, 7] {
                    let d = uniform(users, p, m);
                    let des = sync_round_responses(&model, &d, &state);
                    let closed = model.expected_responses(&d, &state);
                    assert_eq!(des, closed, "users={users} p={p:?} d{m}");
                }
            }
        }
    }

    #[test]
    fn open_loop_completes_every_request() {
        let users = 3;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 20_000.0, 5);
        let d = uniform(users, Tier::Edge(0), 7);
        let out = run_open_loop(&model, &state, &d, &trace, 20_000.0, 6);
        assert_eq!(out.completed.len(), trace.len());
        let mut ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        let mut want: Vec<u64> = trace.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want);
    }

    /// Default calibration with service noise disabled.
    fn quiet_cal() -> Calibration {
        Calibration { noise_sigma: 0.0, ..Calibration::default() }
    }

    #[test]
    fn idle_single_request_matches_service_plus_path() {
        let users = 1;
        let (_, state) = setup(users);
        let trace = vec![Request { id: 0, device: 0, arrival_ms: 10.0 }];
        let d = uniform(users, Tier::Cloud, 0);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let out = run_open_loop(&model, &state, &d, &trace, 100.0, 1);
        let c = &out.completed[0];
        let want = model.net.path_overhead_ms(0, Tier::Cloud)
            + model.single_stream_service_ms(0, ModelId(0), Tier::Cloud, &state);
        assert!((c.response_ms - want).abs() < 1e-9, "{} vs {want}", c.response_ms);
        assert_eq!(c.link_wait_ms, 0.0);
        assert_eq!(c.queue_ms, 0.0);
    }

    #[test]
    fn simultaneous_uploads_serialize_at_the_link() {
        let users = 4;
        let (_, state) = setup(users);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = uniform(users, Tier::Cloud, 7);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        let lq = model.net.cal.link_queue_ms;
        for (j, w) in waits.iter().enumerate() {
            assert!((w - j as f64 * lq).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn saturating_a_device_builds_queue() {
        let users = 1;
        let (model, state) = setup(users);
        // d0 local takes ~440 ms; arrivals every 100 ms pile up.
        let trace: Vec<Request> = (0..10)
            .map(|i| Request { id: i, device: 0, arrival_ms: i as f64 * 100.0 })
            .collect();
        let d = uniform(users, Tier::Local, 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1000.0, 3);
        assert_eq!(out.completed.len(), 10);
        assert!(out.mean_queueing_ms() > 500.0, "queue {:.0}", out.mean_queueing_ms());
        // FIFO: departures in arrival order for a single device
        let ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn event_times_monotone_and_runs_bit_exact() {
        let users = 5;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 5.0 }, users, 10_000.0, 9);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let a = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        let b = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        for w in a.event_times.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(a.responses_ms(), b.responses_ms(), "same seed must be bit-exact");
        let c = run_open_loop(&model, &state, &d, &trace, 10_000.0, 12);
        assert_ne!(a.responses_ms(), c.responses_ms(), "noise seed must matter");
    }

    #[test]
    fn edge_vcpus_bound_concurrency() {
        // 2 edge vCPUs (Table 6): 4 simultaneous edge requests run 2 at a
        // time, so two of them wait ~ one service time in the FIFO.
        let users = 4;
        let (_, state) = setup(users);
        // zero link slot isolates the compute queue
        let cal = Calibration { link_queue_ms: 0.0, ..quiet_cal() };
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), cal));
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = uniform(users, Tier::Edge(0), 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 4);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Edge(0), &state);
        let mut queues: Vec<f64> = out.completed.iter().map(|c| c.queue_ms).collect();
        queues.sort_by(f64::total_cmp);
        assert_eq!(queues.iter().filter(|&&q| q < 1e-9).count(), 2, "{queues:?}");
        assert!((queues[2] - svc).abs() < 1e-6 && (queues[3] - svc).abs() < 1e-6);
    }

    #[test]
    fn two_edges_serialize_uploads_independently() {
        // 4 simultaneous edge uploads, split 2 + 2 across two edges: each
        // link serializes only its own pair, so the per-link waits are
        // {0, lq} instead of the single-edge {0, lq, 2lq, 3lq}.
        let users = 4;
        let cal = quiet_cal();
        let model = ResponseModel::new(Network::with_edges(Scenario::exp_a(users), cal, 2));
        let state = TopoState::idle(&model.net.topo);
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = Decision(
            (0..users)
                .map(|i| Action { placement: Placement::Edge(i % 2), model: ModelId(7) })
                .collect(),
        );
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let lq = model.net.cal.link_queue_ms;
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        assert_eq!(out.completed.len(), users);
        for (j, w) in waits.iter().enumerate() {
            // two links, two holds each: waits 0, 0, lq, lq
            let want = if j < 2 { 0.0 } else { lq };
            assert!((w - want).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn sync_scratch_reuse_matches_alloc_api() {
        // One scratch serves rounds of different decisions, states and
        // even different user counts/topologies, bit-exactly.
        let mut scratch = SyncScratch::new();
        let mut buf = Vec::new();
        for users in 1..=4 {
            let (model, state) = setup(users);
            for m in [0u8, 3, 7] {
                for p in Tier::ALL {
                    let d = uniform(users, p, m);
                    sync_round_responses_into(&model, &d, &state, &mut scratch, &mut buf);
                    let fresh = sync_round_responses(&model, &d, &state);
                    assert_eq!(buf, fresh, "users={users} p={p:?} d{m}");
                }
            }
        }
    }

    #[test]
    fn des_core_reuse_is_bit_exact_and_isolated() {
        let users = 5;
        let (model, state) = setup(users);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let t1 = schedule(ArrivalProcess::Poisson { rate_per_s: 3.0 }, users, 8_000.0, 21);
        let t2 = schedule(
            ArrivalProcess::Mmpp {
                calm_rate_per_s: 0.5,
                burst_rate_per_s: 5.0,
                mean_phase_ms: 1500.0,
            },
            users,
            6_000.0,
            22,
        );
        let a1 = run_open_loop(&model, &state, &d, &t1, 8_000.0, 31);
        let a2 = run_open_loop(&model, &state, &d, &t2, 6_000.0, 32);

        let same = |x: &DesOutcome, y: &DesOutcome| {
            assert_eq!(x.completed.len(), y.completed.len());
            for (a, b) in x.completed.iter().zip(&y.completed) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.response_ms.to_bits(), b.response_ms.to_bits());
                assert_eq!(a.depart_ms.to_bits(), b.depart_ms.to_bits());
                assert_eq!(a.link_wait_ms.to_bits(), b.link_wait_ms.to_bits());
                assert_eq!(a.queue_ms.to_bits(), b.queue_ms.to_bits());
                assert_eq!(a.service_ms.to_bits(), b.service_ms.to_bits());
            }
            assert_eq!(x.makespan_ms.to_bits(), y.makespan_ms.to_bits());
        };

        let mut core = DesCore::new();
        core.install(&model, &state);
        let mut out = DesOutcome::default();
        core.run_open_loop_into(&d, &t1, 8_000.0, 31, &mut out);
        same(&out, &a1);
        // event-time collection is opt-in; the hot path skips it
        assert!(out.event_times.is_empty());
        // a second, different trace through the same arena...
        core.run_open_loop_into(&d, &t2, 6_000.0, 32, &mut out);
        same(&out, &a2);
        // ...and the first again: no state leaks between runs
        core.run_open_loop_into(&d, &t1, 8_000.0, 31, &mut out);
        same(&out, &a1);
    }

    #[test]
    fn service_table_pins_single_stream_law_bitwise() {
        // The memoized tables must be the exact pre-refactor per-request
        // law — same function, evaluated once — including under busy
        // background states that exercise every multiplier.
        for edges in 1..=3usize {
            let users = 4;
            let model = ResponseModel::new(Network::with_edges(
                Scenario::exp_b(users),
                Calibration::default(),
                edges,
            ));
            let mut state = TopoState::idle(&model.net.topo);
            state.devices[0].cpu = 0.9; // busy end device
            state.devices[1].mem = 0.8; // memory pressure
            state.edges[0].cpu = 0.7; // loaded edge
            state.cloud.cpu = 0.4;
            state.cloud.mem = 0.9;
            let mut core = DesCore::new();
            core.install(&model, &state);
            for device in 0..users {
                for m in 0..8u8 {
                    for p in model.net.topo.placements() {
                        let table = core.service_ms(device, ModelId(m), p);
                        let law =
                            model.single_stream_service_ms(device, ModelId(m), p, &state);
                        assert_eq!(table.to_bits(), law.to_bits(), "svc {device}/{m}/{p:?}");
                        let path = core.path_ms(device, p);
                        let want = model.net.path_overhead_ms(device, p);
                        assert_eq!(path.to_bits(), want.to_bits(), "path {device}/{p:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn golden_edge_pair_trace_matches_component_law() {
        // Two simultaneous edge uploads, noise off: responses decompose as
        // path + service (first through the link) and path + link-slot +
        // service (second), all terms straight from the calibrated model —
        // the table-driven engine pinned to the closed-form components.
        let users = 2;
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let (_, state) = setup(users);
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = uniform(users, Tier::Edge(0), 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 7);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Edge(0), &state);
        let path = model.net.path_overhead_ms(0, Tier::Edge(0));
        let lq = model.net.cal.link_queue_ms;
        let mut got: Vec<f64> = out.completed.iter().map(|c| c.response_ms).collect();
        got.sort_by(f64::total_cmp);
        assert!((got[0] - (path + svc)).abs() < 1e-9, "{} vs {}", got[0], path + svc);
        assert!(
            (got[1] - (path + lq + svc)).abs() < 1e-9,
            "{} vs {}",
            got[1],
            path + lq + svc
        );
    }

    #[test]
    fn multi_edge_sync_round_matches_closed_form() {
        for edges in 1..=3usize {
            let users = 6;
            let model = ResponseModel::new(Network::with_edges(
                Scenario::exp_b(users),
                Calibration::default(),
                edges,
            ));
            let state = TopoState::idle(&model.net.topo);
            let d = Decision(
                (0..users)
                    .map(|i| {
                        let placements = model.net.topo.placements();
                        Action {
                            placement: placements[i % placements.len()],
                            model: ModelId((i % 8) as u8),
                        }
                    })
                    .collect(),
            );
            let des = sync_round_responses(&model, &d, &state);
            let closed = model.expected_responses(&d, &state);
            assert_eq!(des, closed, "edges={edges}");
        }
    }
}
