//! Discrete-event simulation core: a binary-heap event queue over virtual
//! time driving per-node multi-server FIFO queues, laid out from the
//! network's [`Topology`](crate::types::Topology) (any number of edge
//! nodes).
//!
//! # Virtual-clock model
//!
//! The simulator owns a virtual clock that only moves when the next event
//! is popped from a min-heap ordered by `(time, seq)` — `seq` is a
//! monotonically increasing tie-breaker, so simultaneous events (e.g. a
//! whole synchronous round arriving at t = 0) are processed in a fixed,
//! deterministic order and a trace is a pure function of its inputs and
//! seed. Wall-clock time never appears: a 10-minute saturation sweep runs
//! in milliseconds, and two runs with the same seed are bit-exact (the
//! property suite asserts this).
//!
//! # Request lifecycle (open-loop mode)
//!
//! ```text
//! arrival --(path_overhead_ms: Table 12 messages)--> [ingress link of the
//!         target's edge] --(seize; holds the link for link_queue_ms)-->
//!         [compute node] --(FIFO over the node's vCPU servers)--> depart
//! ```
//!
//! - Each edge node owns one **ingress link**: a single server that each
//!   offloaded request holds for `link_queue_ms` while being forwarded
//!   immediately. The j-th of k simultaneous uploads on one link therefore
//!   waits (j-1) slots, whose expectation (k-1)/2 x `link_queue_ms` is
//!   exactly the closed-form `Network::queueing_ms` the synchronous model
//!   charges per ingress. Local execution bypasses the links; cloud-bound
//!   requests ride their device's home-edge link
//!   ([`Topology::ingress_edge`](crate::types::Topology::ingress_edge)).
//! - **Compute nodes** (one per end device, one per edge, one cloud) are
//!   multi-server FIFO queues with the topology's per-node vCPU counts
//!   (Table 6 by default). Service demand is
//!   [`ResponseModel::single_stream_service_ms`] — the same calibrated law
//!   as the synchronous round, minus its analytic contention term, because
//!   here contention *is* the queue.
//!
//! # Synchronous-round mode
//!
//! [`sync_round_responses`] runs the same event engine in the paper's
//! §4.2.2 regime: all devices arrive at t = 0 and each request's service
//! time is its full closed-form joint response (processor-sharing
//! contention folded in analytically, infinite servers). This makes the
//! RL environment (`sim::env::Env`) a thin adapter over the DES core while
//! reproducing the seed environment's per-round outcomes exactly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::monitor::StateView;
use crate::sim::latency::{ResponseModel, RoundCtx};
use crate::sim::workload::Request;
use crate::types::{Action, Decision, Placement};
use crate::util::rng::Rng;

/// One finished request with its per-component latency breakdown.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub device: usize,
    pub action: Action,
    pub arrival_ms: f64,
    /// Fixed network path overhead (control + upload messages).
    pub path_ms: f64,
    /// Wait for the target edge's ingress link (0 for local execution).
    pub link_wait_ms: f64,
    /// Wait in the compute node's FIFO before a vCPU was free.
    pub queue_ms: f64,
    /// Service time on the compute node.
    pub service_ms: f64,
    pub depart_ms: f64,
    /// depart - arrival: what the user experienced.
    pub response_ms: f64,
}

/// Outcome of one DES run.
#[derive(Debug, Clone, Default)]
pub struct DesOutcome {
    /// Completed requests in departure order.
    pub completed: Vec<CompletedRequest>,
    /// Virtual time of the last event (makespan).
    pub makespan_ms: f64,
    /// Arrival horizon the trace was generated for.
    pub horizon_ms: f64,
    /// Virtual times of every processed event, in processing order — the
    /// monotonicity witness the property suite checks.
    pub event_times: Vec<f64>,
}

impl DesOutcome {
    /// Completed-request response times, in departure order.
    pub fn responses_ms(&self) -> Vec<f64> {
        self.completed.iter().map(|c| c.response_ms).collect()
    }

    /// Served requests per second of virtual time, over the makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.completed.is_empty() || self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / (self.makespan_ms / 1000.0)
    }

    /// Mean wait (link + compute queue) — the congestion signal the
    /// saturation sweep plots against arrival rate.
    pub fn mean_queueing_ms(&self) -> f64 {
        if self.completed.is_empty() {
            return 0.0;
        }
        self.completed.iter().map(|c| c.link_wait_ms + c.queue_ms).sum::<f64>()
            / self.completed.len() as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// Request reaches a node's queue (ingress pseudo-node or compute).
    Join { node: usize, req: usize },
    /// One hold on edge `link`'s ingress expires; it can admit the next
    /// upload.
    LinkFree { link: usize },
    /// Compute service finishes for `req` on `node`.
    Finish { node: usize, req: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so earliest (time, seq) pops
        // first. total_cmp is a total order (times are never NaN).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Multi-server FIFO queue.
struct ServerQueue {
    servers: usize,
    busy: usize,
    waiting: VecDeque<usize>,
}

impl ServerQueue {
    fn new(servers: usize) -> ServerQueue {
        assert!(servers > 0, "node with zero servers");
        ServerQueue { servers, busy: 0, waiting: VecDeque::new() }
    }
}

/// Per-request in-flight bookkeeping.
struct InFlight {
    id: u64,
    device: usize,
    action: Action,
    arrival_ms: f64,
    path_ms: f64,
    link_enq_ms: f64,
    link_wait_ms: f64,
    compute_enq_ms: f64,
    queue_ms: f64,
    service_ms: f64,
}

/// Open-loop DES over a time-ordered arrival trace.
///
/// Each request executes the action the (frozen) `decision` assigns to its
/// device — the policy snapshot an orchestrator under evaluation installed.
/// `state` is the background-load snapshot service times are computed
/// under (any [`StateView`] whose edge count matches the model's
/// topology), and `noise_seed` drives the multiplicative log-normal
/// service noise (sigma from the calibration; pass the calibration's
/// `noise_sigma = 0` via a custom [`crate::config::Calibration`] to
/// disable it).
pub fn run_open_loop<S: StateView>(
    model: &ResponseModel,
    state: &S,
    decision: &Decision,
    trace: &[Request],
    horizon_ms: f64,
    noise_seed: u64,
) -> DesOutcome {
    let users = state.users();
    let topo = &model.net.topo;
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(topo.users(), users, "topology arity vs state");
    assert_eq!(topo.num_edges(), state.num_edges(), "topology edges vs state");
    assert!(topo.admits(decision), "decision outside topology");
    debug_assert!(
        trace.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
        "trace must be time-ordered"
    );

    // Node layout: [0, users) per-device compute, [users, users + E) the
    // edge nodes, users + E the cloud. Each edge's ingress link is
    // addressed as a pseudo-node after the compute nodes.
    let cal = &model.net.cal;
    let num_edges = topo.num_edges();
    let mut nodes: Vec<ServerQueue> =
        (0..users).map(|i| ServerQueue::new(topo.devices[i].vcpus)).collect();
    for e in &topo.edges {
        nodes.push(ServerQueue::new(e.vcpus));
    }
    nodes.push(ServerQueue::new(topo.cloud.vcpus));
    let mut links: Vec<ServerQueue> = (0..num_edges).map(|_| ServerQueue::new(1)).collect();

    let compute_node = |device: usize, p: Placement| match p {
        Placement::Local => device,
        Placement::Edge(j) => users + j,
        Placement::Cloud => users + num_edges,
    };
    let ingress_base = users + num_edges + 1;

    let mut rng = Rng::new(noise_seed);
    let sigma = cal.noise_sigma;
    let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(trace.len() * 2);
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Event>, seq: &mut u64, time: f64, kind: EventKind| {
        *seq += 1;
        heap.push(Event { time, seq: *seq, kind });
    };

    // Seed the heap: each arrival materializes at its queue-join time
    // after the fixed path overhead.
    let mut flights: Vec<InFlight> = Vec::with_capacity(trace.len());
    for r in trace {
        let action = decision.0[r.device];
        let path_ms = model.net.path_overhead_ms(r.device, action.placement);
        let idx = flights.len();
        flights.push(InFlight {
            id: r.id,
            device: r.device,
            action,
            arrival_ms: r.arrival_ms,
            path_ms,
            link_enq_ms: 0.0,
            link_wait_ms: 0.0,
            compute_enq_ms: 0.0,
            queue_ms: 0.0,
            service_ms: 0.0,
        });
        let target = match topo.ingress_edge(r.device, action.placement) {
            None => compute_node(r.device, Placement::Local),
            Some(link) => ingress_base + link,
        };
        push(&mut heap, &mut seq, r.arrival_ms + path_ms, EventKind::Join { node: target, req: idx });
    }

    let mut out = DesOutcome {
        completed: Vec::with_capacity(trace.len()),
        makespan_ms: 0.0,
        horizon_ms,
        event_times: Vec::with_capacity(trace.len() * 3),
    };

    while let Some(ev) = heap.pop() {
        debug_assert!(ev.time >= out.makespan_ms, "event time went backwards");
        out.makespan_ms = out.makespan_ms.max(ev.time);
        out.event_times.push(ev.time);
        match ev.kind {
            EventKind::Join { node, req } if node >= ingress_base => {
                let link_id = node - ingress_base;
                flights[req].link_enq_ms = ev.time;
                let link = &mut links[link_id];
                if link.busy < link.servers {
                    link.busy += 1;
                    // Forwarded immediately; the hold models the edge's
                    // uplink serializing simultaneous transfers.
                    push(
                        &mut heap,
                        &mut seq,
                        ev.time + cal.link_queue_ms,
                        EventKind::LinkFree { link: link_id },
                    );
                    let f = &flights[req];
                    let target = compute_node(f.device, f.action.placement);
                    push(&mut heap, &mut seq, ev.time, EventKind::Join { node: target, req });
                } else {
                    link.waiting.push_back(req);
                }
            }
            EventKind::LinkFree { link: link_id } => {
                let link = &mut links[link_id];
                link.busy -= 1;
                if let Some(req) = link.waiting.pop_front() {
                    link.busy += 1;
                    flights[req].link_wait_ms = ev.time - flights[req].link_enq_ms;
                    push(
                        &mut heap,
                        &mut seq,
                        ev.time + cal.link_queue_ms,
                        EventKind::LinkFree { link: link_id },
                    );
                    let f = &flights[req];
                    let target = compute_node(f.device, f.action.placement);
                    push(&mut heap, &mut seq, ev.time, EventKind::Join { node: target, req });
                }
            }
            EventKind::Join { node, req } => {
                flights[req].compute_enq_ms = ev.time;
                let q = &mut nodes[node];
                if q.busy < q.servers {
                    q.busy += 1;
                    let f = &flights[req];
                    let mut svc = model.single_stream_service_ms(
                        f.device,
                        f.action.model,
                        f.action.placement,
                        state,
                    );
                    if sigma > 0.0 {
                        svc *= (sigma * rng.normal()).exp();
                    }
                    flights[req].service_ms = svc;
                    push(&mut heap, &mut seq, ev.time + svc, EventKind::Finish { node, req });
                } else {
                    q.waiting.push_back(req);
                }
            }
            EventKind::Finish { node, req } => {
                {
                    let f = &mut flights[req];
                    f.queue_ms = ev.time - f.compute_enq_ms - f.service_ms;
                    out.completed.push(CompletedRequest {
                        id: f.id,
                        device: f.device,
                        action: f.action,
                        arrival_ms: f.arrival_ms,
                        path_ms: f.path_ms,
                        link_wait_ms: f.link_wait_ms,
                        queue_ms: f.queue_ms.max(0.0),
                        service_ms: f.service_ms,
                        depart_ms: ev.time,
                        response_ms: ev.time - f.arrival_ms,
                    });
                }
                let q = &mut nodes[node];
                q.busy -= 1;
                if let Some(next) = q.waiting.pop_front() {
                    q.busy += 1;
                    let f = &flights[next];
                    let mut svc = model.single_stream_service_ms(
                        f.device,
                        f.action.model,
                        f.action.placement,
                        state,
                    );
                    if sigma > 0.0 {
                        svc *= (sigma * rng.normal()).exp();
                    }
                    flights[next].service_ms = svc;
                    push(&mut heap, &mut seq, ev.time + svc, EventKind::Finish { node, req: next });
                }
            }
        }
    }
    out
}

/// One synchronous round (paper §4.2.2) through the event engine.
///
/// All devices arrive at t = 0; each request's service time is its full
/// closed-form joint response (`ResponseModel::device_response_ms` with
/// the round's contention context — the analytic processor-sharing
/// law), executed on infinite servers. The returned vector is indexed by
/// device and equals `ResponseModel::expected_responses` exactly, which is
/// what lets `Env` sit on the DES core without perturbing any seed
/// behavior.
pub fn sync_round_responses<S: StateView>(
    model: &ResponseModel,
    decision: &Decision,
    state: &S,
) -> Vec<f64> {
    let users = state.users();
    assert_eq!(decision.n_users(), users, "decision arity vs users");
    assert_eq!(model.net.topo.num_edges(), state.num_edges(), "topology edges vs state");
    let ctx = RoundCtx::of(&model.net.topo, decision);

    let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(users * 2);
    for device in 0..users {
        heap.push(Event {
            time: 0.0,
            seq: device as u64,
            kind: EventKind::Join { node: device, req: device },
        });
    }

    let mut responses = vec![0.0f64; users];
    let mut seq = users as u64;
    let mut clock = 0.0f64;
    while let Some(ev) = heap.pop() {
        debug_assert!(ev.time >= clock, "event time went backwards");
        clock = clock.max(ev.time);
        match ev.kind {
            EventKind::Join { req: device, .. } => {
                let a = decision.0[device];
                let svc = model.device_response_ms(device, a.model, a.placement, &ctx, state);
                seq += 1;
                heap.push(Event {
                    time: ev.time + svc,
                    seq,
                    kind: EventKind::Finish { node: device, req: device },
                });
            }
            EventKind::Finish { req: device, .. } => {
                responses[device] = ev.time;
            }
            EventKind::LinkFree { .. } => unreachable!("no link events in a synchronous round"),
        }
    }
    responses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Calibration, Scenario};
    use crate::monitor::{NodeState, SystemState, TopoState};
    use crate::network::Network;
    use crate::sim::arrivals::{schedule, ArrivalProcess};
    use crate::types::{ModelId, NetCond, Tier};

    fn setup(users: usize) -> (ResponseModel, SystemState) {
        let model =
            ResponseModel::new(Network::new(Scenario::exp_a(users), Calibration::default()));
        let state = SystemState {
            edge: NodeState::idle(NetCond::Regular),
            cloud: NodeState::idle(NetCond::Regular),
            devices: vec![NodeState::idle(NetCond::Regular); users],
        };
        (model, state)
    }

    fn uniform(users: usize, p: Placement, m: u8) -> Decision {
        Decision::uniform(users, Action { placement: p, model: ModelId(m) })
    }

    #[test]
    fn sync_round_equals_closed_form() {
        for users in 1..=5 {
            let (model, state) = setup(users);
            for p in Tier::ALL {
                for m in [0u8, 3, 7] {
                    let d = uniform(users, p, m);
                    let des = sync_round_responses(&model, &d, &state);
                    let closed = model.expected_responses(&d, &state);
                    assert_eq!(des, closed, "users={users} p={p:?} d{m}");
                }
            }
        }
    }

    #[test]
    fn open_loop_completes_every_request() {
        let users = 3;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 20_000.0, 5);
        let d = uniform(users, Tier::Edge(0), 7);
        let out = run_open_loop(&model, &state, &d, &trace, 20_000.0, 6);
        assert_eq!(out.completed.len(), trace.len());
        let mut ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        let mut want: Vec<u64> = trace.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want);
    }

    /// Default calibration with service noise disabled.
    fn quiet_cal() -> Calibration {
        Calibration { noise_sigma: 0.0, ..Calibration::default() }
    }

    #[test]
    fn idle_single_request_matches_service_plus_path() {
        let users = 1;
        let (_, state) = setup(users);
        let trace = vec![Request { id: 0, device: 0, arrival_ms: 10.0 }];
        let d = uniform(users, Tier::Cloud, 0);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let out = run_open_loop(&model, &state, &d, &trace, 100.0, 1);
        let c = &out.completed[0];
        let want = model.net.path_overhead_ms(0, Tier::Cloud)
            + model.single_stream_service_ms(0, ModelId(0), Tier::Cloud, &state);
        assert!((c.response_ms - want).abs() < 1e-9, "{} vs {want}", c.response_ms);
        assert_eq!(c.link_wait_ms, 0.0);
        assert_eq!(c.queue_ms, 0.0);
    }

    #[test]
    fn simultaneous_uploads_serialize_at_the_link() {
        let users = 4;
        let (_, state) = setup(users);
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), quiet_cal()));
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = uniform(users, Tier::Cloud, 7);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        let lq = model.net.cal.link_queue_ms;
        for (j, w) in waits.iter().enumerate() {
            assert!((w - j as f64 * lq).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn saturating_a_device_builds_queue() {
        let users = 1;
        let (model, state) = setup(users);
        // d0 local takes ~440 ms; arrivals every 100 ms pile up.
        let trace: Vec<Request> = (0..10)
            .map(|i| Request { id: i, device: 0, arrival_ms: i as f64 * 100.0 })
            .collect();
        let d = uniform(users, Tier::Local, 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1000.0, 3);
        assert_eq!(out.completed.len(), 10);
        assert!(out.mean_queueing_ms() > 500.0, "queue {:.0}", out.mean_queueing_ms());
        // FIFO: departures in arrival order for a single device
        let ids: Vec<u64> = out.completed.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn event_times_monotone_and_runs_bit_exact() {
        let users = 5;
        let (model, state) = setup(users);
        let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 5.0 }, users, 10_000.0, 9);
        let d = Decision(
            (0..users)
                .map(|i| Action {
                    placement: Tier::from_index(i % 3),
                    model: ModelId((i % 8) as u8),
                })
                .collect(),
        );
        let a = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        let b = run_open_loop(&model, &state, &d, &trace, 10_000.0, 11);
        for w in a.event_times.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(a.responses_ms(), b.responses_ms(), "same seed must be bit-exact");
        let c = run_open_loop(&model, &state, &d, &trace, 10_000.0, 12);
        assert_ne!(a.responses_ms(), c.responses_ms(), "noise seed must matter");
    }

    #[test]
    fn edge_vcpus_bound_concurrency() {
        // 2 edge vCPUs (Table 6): 4 simultaneous edge requests run 2 at a
        // time, so two of them wait ~ one service time in the FIFO.
        let users = 4;
        let (_, state) = setup(users);
        // zero link slot isolates the compute queue
        let cal = Calibration { link_queue_ms: 0.0, ..quiet_cal() };
        let model = ResponseModel::new(Network::new(Scenario::exp_a(users), cal));
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = uniform(users, Tier::Edge(0), 0);
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 4);
        let svc = model.single_stream_service_ms(0, ModelId(0), Tier::Edge(0), &state);
        let mut queues: Vec<f64> = out.completed.iter().map(|c| c.queue_ms).collect();
        queues.sort_by(f64::total_cmp);
        assert_eq!(queues.iter().filter(|&&q| q < 1e-9).count(), 2, "{queues:?}");
        assert!((queues[2] - svc).abs() < 1e-6 && (queues[3] - svc).abs() < 1e-6);
    }

    #[test]
    fn two_edges_serialize_uploads_independently() {
        // 4 simultaneous edge uploads, split 2 + 2 across two edges: each
        // link serializes only its own pair, so the per-link waits are
        // {0, lq} instead of the single-edge {0, lq, 2lq, 3lq}.
        let users = 4;
        let cal = quiet_cal();
        let model = ResponseModel::new(Network::with_edges(Scenario::exp_a(users), cal, 2));
        let state = TopoState::idle(&model.net.topo);
        let trace: Vec<Request> =
            (0..users).map(|d| Request { id: d as u64, device: d, arrival_ms: 0.0 }).collect();
        let d = Decision(
            (0..users)
                .map(|i| Action { placement: Placement::Edge(i % 2), model: ModelId(7) })
                .collect(),
        );
        let out = run_open_loop(&model, &state, &d, &trace, 1.0, 2);
        let lq = model.net.cal.link_queue_ms;
        let mut waits: Vec<f64> = out.completed.iter().map(|c| c.link_wait_ms).collect();
        waits.sort_by(f64::total_cmp);
        assert_eq!(out.completed.len(), users);
        for (j, w) in waits.iter().enumerate() {
            // two links, two holds each: waits 0, 0, lq, lq
            let want = if j < 2 { 0.0 } else { lq };
            assert!((w - want).abs() < 1e-9, "j={j} wait={w}");
        }
    }

    #[test]
    fn multi_edge_sync_round_matches_closed_form() {
        for edges in 1..=3usize {
            let users = 6;
            let model = ResponseModel::new(Network::with_edges(
                Scenario::exp_b(users),
                Calibration::default(),
                edges,
            ));
            let state = TopoState::idle(&model.net.topo);
            let d = Decision(
                (0..users)
                    .map(|i| {
                        let placements = model.net.topo.placements();
                        Action {
                            placement: placements[i % placements.len()],
                            model: ModelId((i % 8) as u8),
                        }
                    })
                    .collect(),
            );
            let des = sync_round_responses(&model, &d, &state);
            let closed = model.expected_responses(&d, &state);
            assert_eq!(des, closed, "edges={edges}");
        }
    }
}
