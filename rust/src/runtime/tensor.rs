//! Tensor plumbing between the flat-f32 artifact convention and the xla
//! crate's `Literal`s: .bin weight loading, shaped literal construction,
//! and output extraction.

use anyhow::{bail, Context, Result};

/// Read a little-endian f32 `.bin` produced by `aot.py::write_bin`.
pub fn read_f32_bin(path: &str) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write the same format back (checkpointing trained DQN params).
pub fn write_f32_bin(path: &str, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {path}"))
}

/// Build a shaped f32 literal from a flat slice.
pub fn literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {:?} wants {} elements, got {}", dims, n, data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Scalar f32 literal.
pub fn scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_roundtrip() {
        let path = std::env::temp_dir().join("eeco_tensor_test.bin");
        let path = path.to_str().unwrap().to_string();
        let data = vec![1.5f32, -2.25, 0.0, 3.0e7];
        write_f32_bin(&path, &data).unwrap();
        assert_eq!(read_f32_bin(&path).unwrap(), data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_length_rejected() {
        let path = std::env::temp_dir().join("eeco_tensor_bad.bin");
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(read_f32_bin(path.to_str().unwrap()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn literal_shape_checked() {
        assert!(literal(&[1.0, 2.0], &[3]).is_err());
        let l = literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn missing_file_context() {
        let e = read_f32_bin("/nonexistent/x.bin").unwrap_err();
        assert!(format!("{e:#}").contains("/nonexistent/x.bin"));
    }
}
