//! PJRT runtime (Layer 3's bridge to the AOT artifacts).
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): loads HLO *text*
//! artifacts (`HloModuleProto::from_text_file` — see aot.py for why text),
//! compiles them once per process into a cache, holds the flat-f32 weight
//! store, and exposes typed entry points for the serving path
//! (`infer`) and the DQN agent (`dqn_forward` / `dqn_train`).
//!
//! Python never appears here: after `make artifacts` the Rust binary is
//! self-contained.

mod manifest;
pub mod tensor;

pub use manifest::{DqnEntry, GraphEntry, Manifest, ModelEntry};

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};

use crate::types::ModelId;

/// A compiled HLO graph ready to execute.
pub struct LoadedGraph {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedGraph {
    /// Execute with f32 literal inputs; returns the flattened f32 outputs
    /// of the graph's result tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(|p| Ok(p.to_vec::<f32>()?)).collect()
    }
}

/// The artifact runtime: PJRT client + manifest + lazy compile cache +
/// weight store. NOTE: the underlying `xla` crate types are `!Send`
/// (internal `Rc`), so `Runtime` is single-threaded; cross-thread users go
/// through [`SharedRuntime`], which serializes access behind a mutex and
/// only ever moves plain `Vec<f32>` across the boundary.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    graphs: Mutex<HashMap<String, Arc<LoadedGraph>>>,
    weights: Mutex<HashMap<String, Arc<Vec<f32>>>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.validate_against_catalog()?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            graphs: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an HLO-text artifact by file name.
    pub fn graph(&self, file: &str) -> Result<Arc<LoadedGraph>> {
        if let Some(g) = self.graphs.lock().unwrap().get(file) {
            return Ok(Arc::clone(g));
        }
        let path = self.manifest.path(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {file}"))?;
        crate::info!("compiled {file} in {:.2}s", t0.elapsed().as_secs_f64());
        let g = Arc::new(LoadedGraph { name: file.to_string(), exe });
        self.graphs.lock().unwrap().insert(file.to_string(), Arc::clone(&g));
        Ok(g)
    }

    /// Cached flat weight vector for a `.bin` artifact.
    pub fn weights(&self, file: &str) -> Result<Arc<Vec<f32>>> {
        if let Some(w) = self.weights.lock().unwrap().get(file) {
            return Ok(Arc::clone(w));
        }
        let w = Arc::new(tensor::read_f32_bin(&self.manifest.path(file))?);
        self.weights.lock().unwrap().insert(file.to_string(), Arc::clone(&w));
        Ok(w)
    }

    /// Batch sizes available for a model's serving graph, ascending.
    pub fn batches_for(&self, id: ModelId) -> Result<Vec<usize>> {
        let entry = self.manifest.model(id)?;
        Ok(self.manifest.graph(&entry.graph)?.files.keys().copied().collect())
    }

    /// Run MobileNet inference for `id` on a batch of images
    /// (flat NHWC f32, `n` images). Pads to the smallest compiled batch
    /// >= n and truncates the logits back to `n` rows.
    pub fn infer(&self, id: ModelId, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let (h, w, c) = self.manifest.img;
        let classes = self.manifest.classes;
        if images.len() != n * h * w * c {
            return Err(anyhow!(
                "images len {} != n {} * {h}x{w}x{c}",
                images.len(),
                n
            ));
        }
        let entry = self.manifest.model(id)?;
        let graph_entry = self.manifest.graph(&entry.graph)?;
        let &batch = graph_entry
            .files
            .keys()
            .find(|&&b| b >= n)
            .or_else(|| graph_entry.files.keys().last())
            .ok_or_else(|| anyhow!("no batches for {id}"))?;
        if n > batch {
            return Err(anyhow!("batch {n} exceeds max compiled batch {batch} for {id}"));
        }
        let file = &graph_entry.files[&batch];
        let graph = self.graph(file)?;
        let weights = self.weights(&entry.weights)?;

        let mut padded = images.to_vec();
        padded.resize(batch * h * w * c, 0.0);
        let params = tensor::literal(&weights, &[weights.len()])?;
        let imgs = tensor::literal(&padded, &[batch, h, w, c])?;
        let out = graph.execute(&[params, imgs])?;
        let logits = &out[0];
        Ok(logits[..n * classes].to_vec())
    }

    /// DQN forward for `users`: state vector (len D) -> per-device
    /// Q-values, row-major [users x actions_per_device].
    pub fn dqn_forward(&self, users: usize, params: &[f32], state: &[f32]) -> Result<Vec<f32>> {
        let d = self.manifest.dqn_for(users)?;
        if state.len() != d.state_dim || params.len() != d.param_count {
            return Err(anyhow!(
                "dqn_forward dims: state {} (want {}), params {} (want {})",
                state.len(),
                d.state_dim,
                params.len(),
                d.param_count
            ));
        }
        let graph = self.graph(&d.fwd.clone())?;
        let p = tensor::literal(params, &[params.len()])?;
        let s = tensor::literal(state, &[1, d.state_dim])?;
        let out = graph.execute(&[p, s])?;
        Ok(out[0].clone())
    }

    /// One DQN SGD train step over a replay minibatch.
    /// Shapes: s/s2 [B, D] flat; a_onehot [B, users, 24] flat; r [B].
    /// Returns (new_params, loss).
    pub fn dqn_train(
        &self,
        users: usize,
        params: &[f32],
        s: &[f32],
        a_onehot: &[f32],
        r: &[f32],
        s2: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let d = self.manifest.dqn_for(users)?;
        let b = d.train_batch;
        let apd = d.actions_per_device;
        if s.len() != b * d.state_dim || s2.len() != b * d.state_dim {
            return Err(anyhow!("dqn_train state dims"));
        }
        if a_onehot.len() != b * users * apd || r.len() != b {
            return Err(anyhow!("dqn_train batch dims"));
        }
        let graph = self.graph(&d.train.clone())?;
        let out = graph.execute(&[
            tensor::literal(params, &[params.len()])?,
            tensor::literal(s, &[b, d.state_dim])?,
            tensor::literal(a_onehot, &[b, users, apd])?,
            tensor::literal(r, &[b])?,
            tensor::literal(s2, &[b, d.state_dim])?,
            tensor::scalar(lr),
        ])?;
        let new_params = out[0].clone();
        let loss = out[1][0];
        Ok((new_params, loss))
    }

    /// Initial DQN parameters for `users` (from dqn_init_n*.bin).
    pub fn dqn_init(&self, users: usize) -> Result<Vec<f32>> {
        let d = self.manifest.dqn_for(users)?;
        Ok((*self.weights(&d.init.clone())?).clone())
    }

    /// Pre-compile everything the serving path needs (startup warm-up so
    /// first-request latency is not a compile).
    pub fn warmup_serving(&self, models: &[ModelId]) -> Result<()> {
        for &id in models {
            let entry = self.manifest.model(id)?;
            for file in self.manifest.graph(&entry.graph)?.files.values() {
                self.graph(file)?;
            }
            self.weights(&entry.weights.clone())?;
        }
        Ok(())
    }
}

/// `Runtime` wrapped for cross-thread use.
///
/// Safety: every xla object (client, executables, literals, buffers) is
/// created, used and dropped while holding the mutex, so the non-atomic
/// `Rc` refcounts inside the `xla` crate are never touched concurrently.
/// Only plain `Vec<f32>`/`f32` values cross the API boundary.
struct SendCell(Runtime);
// SAFETY: see above — all access is serialized by SharedRuntime's Mutex.
unsafe impl Send for SendCell {}

pub struct SharedRuntime {
    /// Manifest copy readable without taking the runtime lock.
    pub manifest: Manifest,
    inner: Mutex<SendCell>,
}

impl SharedRuntime {
    pub fn load(artifacts_dir: &str) -> Result<SharedRuntime> {
        let rt = Runtime::load(artifacts_dir)?;
        Ok(SharedRuntime { manifest: rt.manifest.clone(), inner: Mutex::new(SendCell(rt)) })
    }

    fn with<T>(&self, f: impl FnOnce(&Runtime) -> T) -> T {
        let guard = self.inner.lock().unwrap();
        f(&guard.0)
    }

    pub fn infer(&self, id: ModelId, images: &[f32], n: usize) -> Result<Vec<f32>> {
        self.with(|rt| rt.infer(id, images, n))
    }

    pub fn dqn_forward(&self, users: usize, params: &[f32], state: &[f32]) -> Result<Vec<f32>> {
        self.with(|rt| rt.dqn_forward(users, params, state))
    }

    #[allow(clippy::too_many_arguments)]
    pub fn dqn_train(
        &self,
        users: usize,
        params: &[f32],
        s: &[f32],
        a_onehot: &[f32],
        r: &[f32],
        s2: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.with(|rt| rt.dqn_train(users, params, s, a_onehot, r, s2, lr))
    }

    pub fn dqn_init(&self, users: usize) -> Result<Vec<f32>> {
        self.with(|rt| rt.dqn_init(users))
    }

    pub fn warmup_serving(&self, models: &[ModelId]) -> Result<()> {
        self.with(|rt| rt.warmup_serving(models))
    }
}

/// Shared runtime for tests/benches (compiling MobileNet graphs takes
/// seconds; do it once per process).
pub fn shared(artifacts_dir: &str) -> &'static SharedRuntime {
    static RT: OnceLock<SharedRuntime> = OnceLock::new();
    RT.get_or_init(|| SharedRuntime::load(artifacts_dir).expect("runtime load"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Option<Runtime> {
        let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(&format!("{d}/manifest.json"))
            .exists()
            .then(|| Runtime::load(d).unwrap())
    }

    #[test]
    fn kernel_demo_matches_golden() {
        let Some(rt) = rt() else { return };
        let kd = rt.manifest.raw.field("kernel_demo").unwrap();
        let (m, k, n) = (
            kd.field("m").unwrap().as_usize().unwrap(),
            kd.field("k").unwrap().as_usize().unwrap(),
            kd.field("n").unwrap().as_usize().unwrap(),
        );
        let g = rt.graph(kd.field("file").unwrap().as_str().unwrap()).unwrap();
        let x = tensor::read_f32_bin(&rt.manifest.path("goldens/matmul_x.bin")).unwrap();
        let w = tensor::read_f32_bin(&rt.manifest.path("goldens/matmul_w.bin")).unwrap();
        let want = tensor::read_f32_bin(&rt.manifest.path("goldens/matmul_y.bin")).unwrap();
        let out = g
            .execute(&[
                tensor::literal(&x, &[m, k]).unwrap(),
                tensor::literal(&w, &[k, n]).unwrap(),
            ])
            .unwrap();
        assert_eq!(out[0].len(), want.len());
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn graph_cache_returns_same_arc() {
        let Some(rt) = rt() else { return };
        let f = "kernel_matmul.hlo.txt";
        let a = rt.graph(f).unwrap();
        let b = rt.graph(f).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn infer_rejects_bad_sizes() {
        let Some(rt) = rt() else { return };
        assert!(rt.infer(ModelId(0), &[0.0; 10], 1).is_err());
        let (h, w, c) = rt.manifest.img;
        let img = vec![0.0; 100 * h * w * c];
        assert!(rt.infer(ModelId(0), &img, 100).is_err()); // > max batch
    }
}
