//! Typed view over `artifacts/manifest.json` (produced by
//! `python/compile/aot.py`): the model catalog with graph/weight file
//! mappings, the DQN artifact set, and golden references for integration
//! tests.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::types::ModelId;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct GraphEntry {
    /// batch size -> HLO text file name
    pub files: BTreeMap<usize, String>,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub id: ModelId,
    pub alpha: f64,
    pub dtype: String,
    pub top5: f64,
    pub mmacs: f64,
    pub graph: String,
    pub weights: String,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct DqnEntry {
    pub fwd: String,
    pub train: String,
    pub init: String,
    pub state_dim: usize,
    pub hidden: usize,
    pub actions_per_device: usize,
    pub param_count: usize,
    pub train_batch: usize,
    pub gamma: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: String,
    pub use_pallas: bool,
    pub img: (usize, usize, usize),
    pub classes: usize,
    pub models: Vec<ModelEntry>,
    pub graphs: BTreeMap<String, GraphEntry>,
    pub dqn: BTreeMap<usize, DqnEntry>,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("{path} (run `make artifacts` first)"))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("parse {path}: {e}"))?;

        let img = j.field("image").map_err(|e| anyhow!(e))?;
        let geta = |k: &str| -> Result<usize> {
            img.field(k).map_err(|e| anyhow!(e))?.as_usize().ok_or_else(|| anyhow!("image.{k}"))
        };

        let mut graphs = BTreeMap::new();
        for (name, g) in j.field("graphs").map_err(|e| anyhow!(e))?.as_obj().unwrap() {
            let mut files = BTreeMap::new();
            for (b, f) in g.field("files").map_err(|e| anyhow!(e))?.as_obj().unwrap() {
                files.insert(
                    b.parse::<usize>().map_err(|e| anyhow!("batch key {b}: {e}"))?,
                    f.as_str().unwrap().to_string(),
                );
            }
            graphs.insert(
                name.clone(),
                GraphEntry {
                    files,
                    param_count: g
                        .field("param_count")
                        .map_err(|e| anyhow!(e))?
                        .as_usize()
                        .unwrap(),
                },
            );
        }

        let mut models = Vec::new();
        for m in j.field("models").map_err(|e| anyhow!(e))?.as_arr().unwrap() {
            let id_str = m.field("id").map_err(|e| anyhow!(e))?.as_str().unwrap();
            let idx: u8 = id_str.trim_start_matches('d').parse()?;
            models.push(ModelEntry {
                id: ModelId(idx),
                alpha: m.field("alpha").map_err(|e| anyhow!(e))?.as_f64().unwrap(),
                dtype: m.field("dtype").map_err(|e| anyhow!(e))?.as_str().unwrap().into(),
                top5: m.field("top5").map_err(|e| anyhow!(e))?.as_f64().unwrap(),
                mmacs: m.field("mmacs").map_err(|e| anyhow!(e))?.as_f64().unwrap(),
                graph: m.field("graph").map_err(|e| anyhow!(e))?.as_str().unwrap().into(),
                weights: m.field("weights").map_err(|e| anyhow!(e))?.as_str().unwrap().into(),
                param_count: m.field("param_count").map_err(|e| anyhow!(e))?.as_usize().unwrap(),
            });
        }
        models.sort_by_key(|m| m.id);

        let mut dqn = BTreeMap::new();
        for (n, d) in j.field("dqn").map_err(|e| anyhow!(e))?.as_obj().unwrap() {
            let gf = |k: &str| -> Result<&Json> { d.field(k).map_err(|e| anyhow!(e)) };
            dqn.insert(
                n.parse::<usize>()?,
                DqnEntry {
                    fwd: gf("fwd")?.as_str().unwrap().into(),
                    train: gf("train")?.as_str().unwrap().into(),
                    init: gf("init")?.as_str().unwrap().into(),
                    state_dim: gf("state_dim")?.as_usize().unwrap(),
                    hidden: gf("hidden")?.as_usize().unwrap(),
                    actions_per_device: gf("actions_per_device")?.as_usize().unwrap(),
                    param_count: gf("param_count")?.as_usize().unwrap(),
                    train_batch: gf("train_batch")?.as_usize().unwrap(),
                    gamma: gf("gamma")?.as_f64().unwrap(),
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_string(),
            use_pallas: j.field("use_pallas").map_err(|e| anyhow!(e))?.as_bool().unwrap_or(true),
            img: (geta("h")?, geta("w")?, geta("c")?),
            classes: geta("classes")?,
            models,
            graphs,
            dqn,
            raw: j,
        })
    }

    pub fn model(&self, id: ModelId) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.id == id)
            .ok_or_else(|| anyhow!("model {id} not in manifest"))
    }

    pub fn graph(&self, name: &str) -> Result<&GraphEntry> {
        self.graphs.get(name).ok_or_else(|| anyhow!("graph {name} not in manifest"))
    }

    pub fn dqn_for(&self, users: usize) -> Result<&DqnEntry> {
        self.dqn.get(&users).ok_or_else(|| {
            anyhow!("no DQN artifact for {users} users (built: {:?})", self.dqn.keys())
        })
    }

    pub fn path(&self, file: &str) -> String {
        format!("{}/{file}", self.dir)
    }

    /// Cross-check against the static Table 4 catalog (DESIGN.md: MAC
    /// ratios must match even though absolute MACs differ by geometry).
    pub fn validate_against_catalog(&self) -> Result<()> {
        for m in &self.models {
            let cat = crate::models::info(m.id);
            if (cat.top5 - m.top5).abs() > 1e-6 {
                return Err(anyhow!("{}: top5 mismatch manifest={} catalog={}", m.id, m.top5, cat.top5));
            }
            if (cat.alpha - m.alpha).abs() > 1e-9 {
                return Err(anyhow!("{}: alpha mismatch", m.id));
            }
        }
        // MAC ratio d0/d3 within 2x of the paper's 569/41
        let r_ours = self.model(ModelId(0))?.mmacs / self.model(ModelId(3))?.mmacs;
        let r_paper = 569.0 / 41.0;
        if !(r_paper / 2.0..r_paper * 2.0).contains(&r_ours) {
            return Err(anyhow!("MAC ratio drifted: ours {r_ours:.1} paper {r_paper:.1}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<String> {
        let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(&format!("{d}/manifest.json")).exists().then(|| d.to_string())
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 8);
        assert_eq!(m.img.0, 64);
        assert!(m.graphs.len() >= 4);
        assert!(m.dqn.contains_key(&3) && m.dqn.contains_key(&5));
        m.validate_against_catalog().unwrap();
    }

    #[test]
    fn model_and_graph_lookup() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let d0 = m.model(ModelId(0)).unwrap();
        assert_eq!(d0.dtype, "fp32");
        let g = m.graph(&d0.graph).unwrap();
        assert!(g.files.contains_key(&1));
        assert!(g.files.contains_key(&8));
        assert_eq!(g.param_count, d0.param_count);
        // int8 variant shares the fp32 graph
        let d4 = m.model(ModelId(4)).unwrap();
        assert_eq!(d4.graph, d0.graph);
        assert_ne!(d4.weights, d0.weights);
    }

    #[test]
    fn missing_dir_errors_with_hint() {
        let e = Manifest::load("/nonexistent").unwrap_err();
        assert!(format!("{e:#}").contains("make artifacts"));
    }
}
