//! The Intelligent Orchestrator (paper Fig. 4): drives the RL agent over
//! the synchronous-round environment — training with convergence
//! detection (Fig. 6/7, Table 11), greedy evaluation (Fig. 5, Tables 8/9),
//! and the prediction-accuracy check against the brute-force optimum
//! (§6.1's "100% prediction accuracy" experiment).

use std::sync::Arc;

use crate::agent::{bruteforce, Agent};
use crate::metrics::{RoundRecord, RunMetrics, TrafficMetrics};
use crate::monitor::{EncodedState, TopoState};
use crate::sim::Env;
use crate::types::Decision;
use crate::util::pool::ThreadPool;
use crate::util::stats::Convergence;

/// Training-curve point: (step, windowed average reward).
pub type CurvePoint = (usize, f64);

#[derive(Debug)]
pub struct TrainResult {
    pub steps: usize,
    pub converged_at: Option<usize>,
    /// Windowed average-reward curve (Fig. 6's y-axis).
    pub curve: Vec<CurvePoint>,
}

pub struct Orchestrator {
    pub env: Env,
    pub agent: Box<dyn Agent>,
}

impl Orchestrator {
    pub fn new(env: Env, agent: Box<dyn Agent>) -> Orchestrator {
        Orchestrator { env, agent }
    }

    /// One orchestrated round (Fig. 4 steps 1-5): observe state, decide,
    /// execute, reward, learn.
    pub fn round(&mut self, explore: bool) -> RoundRecord {
        self.round_with(explore, None).0
    }

    /// [`Orchestrator::round`] with an optional pre-encoded state: round
    /// t's post-step encoding is round t+1's state, so the training and
    /// evaluation loops thread it back in instead of re-encoding — halving
    /// monitor encodes over a whole run. Callers must only pass an
    /// encoding produced by the immediately preceding round (the loops
    /// below hold `&mut self` across rounds, so nothing can mutate the
    /// environment in between); `None` encodes fresh, which is always
    /// correct.
    fn round_with(
        &mut self,
        explore: bool,
        cached: Option<EncodedState>,
    ) -> (RoundRecord, EncodedState) {
        let state = cached.unwrap_or_else(|| self.env.encoded());
        // The exploration rate that governed *this* decision (the learn()
        // below advances the agent's schedule).
        let epsilon = if explore { self.agent.epsilon() } else { 0.0 };
        let decision = self.agent.decide(&state, explore);
        let out = self.env.step(&decision);
        let next = self.env.encoded();
        if explore {
            self.agent.learn(&state, &decision, out.reward, &next);
        }
        let rec = RoundRecord {
            step: self.agent.steps(),
            decision,
            avg_response_ms: out.avg_ms,
            avg_accuracy: out.avg_accuracy,
            reward: out.reward,
            epsilon,
            response_ms: out.responses_ms,
        };
        (rec, next)
    }

    /// The one training loop: run up to `steps` exploring rounds, sample
    /// the windowed average-reward curve every `curve_every` rounds, and —
    /// when `stop_at_convergence` — break once the rolling-window mean of
    /// the reward is stable within 1% for the patience window (Table 11's
    /// stopping rule). [`Orchestrator::train`] and
    /// [`Orchestrator::train_full`] are the two calling conventions.
    fn train_loop(
        &mut self,
        steps: usize,
        curve_every: usize,
        stop_at_convergence: bool,
    ) -> TrainResult {
        let window = (steps / 100).clamp(10, 2000);
        let mut conv = Convergence::new(window, 0.01, 3);
        let mut curve = Vec::new();
        let mut acc = 0.0;
        let mut count = 0usize;
        // Thread each round's post-step encoding into the next round
        // (sound here: this loop owns &mut self between rounds).
        let mut carry: Option<EncodedState> = None;
        for step in 0..steps {
            let (rec, next) = self.round_with(true, carry.take());
            carry = Some(next);
            conv.push(rec.reward);
            acc += rec.reward;
            count += 1;
            if (step + 1) % curve_every.max(1) == 0 {
                curve.push((step + 1, acc / count as f64));
                acc = 0.0;
                count = 0;
            }
            if stop_at_convergence && conv.is_converged() && step > 2 * window {
                break;
            }
        }
        TrainResult { steps: self.agent.steps(), converged_at: conv.converged_at, curve }
    }

    /// Train until `max_steps` or convergence (rolling-window mean of the
    /// reward stable within 1% for `patience` windows). `curve_every`
    /// controls the sampling density of the returned curve.
    pub fn train(&mut self, max_steps: usize, curve_every: usize) -> TrainResult {
        self.train_loop(max_steps, curve_every, true)
    }

    /// Train for exactly `steps` rounds (full curves for Fig. 6/7).
    pub fn train_full(&mut self, steps: usize, curve_every: usize) -> TrainResult {
        self.train_loop(steps, curve_every, false)
    }

    /// Greedy evaluation over `rounds` (no exploration, no learning).
    pub fn evaluate(&mut self, rounds: usize) -> RunMetrics {
        let mut m = RunMetrics::new();
        let mut carry: Option<EncodedState> = None;
        for _ in 0..rounds {
            let (rec, next) = self.round_with(false, carry.take());
            carry = Some(next);
            m.push(&rec);
        }
        m
    }

    /// Asynchronous (open-loop) evaluation: score the greedy policy under
    /// stochastic arrivals instead of synchronous rounds.
    ///
    /// The agent's greedy decision at the current monitored state is
    /// installed as the routing policy, an arrival trace is generated from
    /// `process` over `horizon_ms`, and the DES core plays it through the
    /// per-node vCPU queues. The returned [`TrafficMetrics`] carry
    /// *per-request* response percentiles (p50/p95/p99) and throughput —
    /// the open-loop quality signal round averages cannot express.
    /// Deterministic for a fixed `seed` (trace and service noise both
    /// derive from it).
    pub fn evaluate_async(
        &mut self,
        process: crate::sim::ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
    ) -> TrafficMetrics {
        let state = self.env.encoded();
        let decision = self.agent.decide(&state, false);
        let users = self.env.users();
        let trace = crate::sim::arrivals::schedule(process, users, horizon_ms, seed);
        let outcome = self.env.open_loop(&decision, &trace, horizon_ms, seed ^ 0x5EED_DE5);
        TrafficMetrics::from_outcome(&decision, &outcome)
    }

    /// The representative greedy decision at the idle system state —
    /// what the paper's Tables 8/9/10 print per scenario.
    pub fn representative_decision(&mut self) -> (Decision, f64, f64) {
        self.env.reset_load();
        let state = self.env.encoded();
        let decision = self.agent.decide(&state, false);
        let avg = self.env.expected_avg_ms(&decision);
        let acc = self.env.accuracy_of(&decision);
        (decision, avg, acc)
    }

    /// Fraction of greedy decisions matching the brute-force optimum's
    /// objective value over `trials` evolving states (§6.1: the paper
    /// reports 100% after convergence). Matching is by expected average
    /// response (distinct decisions can tie exactly).
    ///
    /// Trials where the oracle declines to score (instances past its
    /// enumeration budget, see [`bruteforce::optimal`]) are skipped rather
    /// than counted as misses; the returned rate is over scored trials
    /// only, and 0.0 — never NaN — when nothing could be scored. Callers
    /// that must distinguish "0% hit-rate" from "nothing scorable" use
    /// [`Orchestrator::prediction_accuracy_scored`].
    pub fn prediction_accuracy(&mut self, trials: usize, tol: f64) -> f64 {
        self.prediction_accuracy_scored(trials, tol).0
    }

    /// [`Orchestrator::prediction_accuracy`] plus how many of the
    /// `trials` the oracle actually scored — 0 scored means the rate
    /// carries no information (the instance is past the oracle budget).
    ///
    /// The rollout is serial (each trial's state depends on the previous
    /// decision's execution), but the expensive part — the brute-force
    /// oracle — is a pure function of (model, state snapshot), so the
    /// per-trial oracle calls fan out across a thread pool and come back
    /// in trial order: results are bit-identical to the serial loop.
    pub fn prediction_accuracy_scored(&mut self, trials: usize, tol: f64) -> (f64, usize) {
        if trials == 0 {
            return (0.0, 0);
        }
        // Phase 1 (serial): roll the environment forward exactly as the
        // sequential version did, snapshotting each trial's background
        // state for the oracle.
        let mut snaps: Vec<(f64, bool, TopoState)> = Vec::with_capacity(trials);
        for _ in 0..trials {
            let state = self.env.encoded();
            let decision = self.agent.decide(&state, false);
            let ours = self.env.expected_avg_ms(&decision);
            let acc_ok = self.env.accuracy_of(&decision) > self.env.threshold;
            snaps.push((ours, acc_ok, self.env.state.clone()));
            // advance dynamics by actually executing the chosen decision
            self.env.step(&decision);
        }
        // Phase 2 (parallel): score every snapshot against the optimum.
        let model = Arc::new(self.env.model.clone());
        let threshold = self.env.threshold;
        let workers =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(trials);
        let pool = ThreadPool::new(workers, "oracle");
        let verdicts: Vec<Option<bool>> =
            pool.map_indexed(snaps, move |_, (ours, acc_ok, snap)| {
                bruteforce::optimal_for(model.as_ref(), &snap, threshold)
                    .map(|(_, best)| acc_ok && (ours - best) / best <= tol)
            });
        let scored = verdicts.iter().filter(|v| v.is_some()).count();
        if scored == 0 {
            return (0.0, 0);
        }
        let hits = verdicts.iter().filter(|v| **v == Some(true)).count();
        (hits as f64 / scored as f64, scored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::baseline::FixedAgent;
    use crate::agent::qlearning::QTableAgent;
    use crate::agent::ActionSet;
    use crate::config::{Algo, Calibration, Hyper, Scenario};
    use crate::types::{AccuracyConstraint, Tier};

    fn env(users: usize, c: AccuracyConstraint) -> Env {
        Env::new(Scenario::exp_a(users), Calibration::default(), c, 11)
    }

    fn ql(users: usize) -> Box<dyn Agent> {
        Box::new(QTableAgent::new(
            users,
            Hyper::paper_defaults(Algo::QLearning, users),
            ActionSet::full(),
            13,
        ))
    }

    #[test]
    fn round_records_are_consistent() {
        let mut o = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        let rec = o.round(true);
        assert_eq!(rec.response_ms.len(), 2);
        assert!(rec.avg_response_ms > 0.0);
        assert_eq!(o.agent.steps(), 1);
    }

    #[test]
    fn training_improves_over_random() {
        let mut o = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        o.env.freeze(); // single state: tabular convergence is exact
        let before = o.evaluate(50).response.mean();
        let _ = o.train_full(15_000, 5000);
        let after = o.evaluate(50).response.mean();
        assert!(
            after < before,
            "training should reduce avg response: {after} !< {before}"
        );
        // trained policy within 40% of the brute-force optimum (the
        // factored learner with lr 0.9 and shared rewards bounces between
        // near-equivalent smallest models; the experiment drivers use the
        // oracle fallback for table-exact decisions)
        o.env.reset_load();
        let (_, best) = bruteforce::optimal(&o.env, o.env.threshold).unwrap();
        let (_, ours, _) = o.representative_decision();
        assert!(ours <= best * 1.4, "ours={ours} best={best}");
    }

    #[test]
    fn fixed_agent_evaluation_matches_anchor() {
        let users = 5;
        let mut o = Orchestrator::new(
            env(users, AccuracyConstraint::Max),
            Box::new(FixedAgent::new(Tier::Local, users)),
        );
        o.env.freeze(); // idle background: the Fig 5 anchor setting
        let m = o.evaluate(20).response.mean();
        assert!((m - 459.0).abs() < 20.0, "device-only avg {m}");
    }

    #[test]
    fn evaluation_does_not_learn() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o.evaluate(10);
        assert_eq!(o.agent.steps(), 0);
    }

    #[test]
    fn round_records_surface_real_epsilon() {
        let users = 2;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        let hyper = crate::config::Hyper::paper_defaults(
            crate::config::Algo::QLearning,
            users,
        );
        // first exploring round sees the schedule's step-0 value (1.0)
        let rec = o.round(true);
        assert_eq!(rec.epsilon, hyper.epsilon_at(0));
        // subsequent rounds track the decaying schedule, not NaN
        for step in 1..20 {
            let rec = o.round(true);
            assert!(rec.epsilon.is_finite());
            assert_eq!(rec.epsilon, hyper.epsilon_at(step));
        }
        // greedy evaluation reports zero exploration
        assert_eq!(o.round(false).epsilon, 0.0);
    }

    #[test]
    fn async_evaluation_reports_percentiles_and_throughput() {
        let users = 3;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        o.env.freeze();
        o.env.reset_load();
        let m = o.evaluate_async(
            crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.0 },
            10_000.0,
            3,
        );
        assert!(m.requests > 10, "requests {}", m.requests);
        assert!(m.response.p50_ms > 0.0);
        assert!(m.response.p50_ms <= m.response.p95_ms);
        assert!(m.response.p95_ms <= m.response.p99_ms);
        assert!(m.throughput_rps > 0.0);
        assert_eq!(m.decision.n_users(), users);
    }

    #[test]
    fn prediction_accuracy_skips_declined_oracle_and_never_nans() {
        // 8 users: past the oracle's enumeration budget, every trial is
        // declined -> 0.0 over zero scored trials, not NaN.
        let users = 8;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        let acc = o.prediction_accuracy(3, 0.02);
        assert_eq!(acc, 0.0);
        assert!(acc.is_finite());
        // the scored count disambiguates "0% hit-rate" from "unscorable"
        assert_eq!(o.prediction_accuracy_scored(3, 0.02), (0.0, 0));
        // zero trials is also defined
        let mut o2 = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        assert_eq!(o2.prediction_accuracy(0, 0.02), 0.0);
    }

    #[test]
    fn train_full_runs_exact_budget_train_may_stop_early() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        let full = o.train_full(500, 100);
        assert_eq!(full.steps, 500);
        assert_eq!(full.curve.len(), 5);
        // `train` shares the loop but may stop at convergence
        let mut o2 = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o2.env.freeze();
        let early = o2.train(20_000, 1000);
        assert!(early.steps <= 20_000);
        if let Some(at) = early.converged_at {
            assert!(at <= early.steps);
        }
    }

    #[test]
    fn cached_state_threading_matches_uncached_rounds() {
        // train_loop/evaluate reuse round t's post-step encoding as round
        // t+1's state; with identical seeds that must be behaviorally
        // indistinguishable from re-encoding every round (encode is pure).
        let mut a = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        let mut b = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        // a: uncached public rounds; b: the cached training loop
        let ra: Vec<f64> = (0..300).map(|_| a.round(true).reward).collect();
        let _ = b.train_full(300, 300);
        assert_eq!(a.agent.steps(), b.agent.steps());
        // identical value functions -> identical greedy trajectories, and
        // identical env rng streams -> bit-equal rewards from here on
        for _ in 0..5 {
            let x = a.round(false);
            let y = b.round(false);
            assert_eq!(x.decision, y.decision);
            assert_eq!(x.reward.to_bits(), y.reward.to_bits());
        }
        assert!(ra.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn trained_agent_predicts_optimum_frozen_env() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o.env.freeze();
        let _ = o.train_full(3000, 1000);
        let acc = o.prediction_accuracy(10, 0.02);
        assert!(acc >= 0.9, "prediction accuracy {acc}");
    }
}
