//! The Intelligent Orchestrator (paper Fig. 4): drives the RL agent over
//! the synchronous-round environment — training with convergence
//! detection (Fig. 6/7, Table 11), greedy evaluation (Fig. 5, Tables 8/9),
//! and the prediction-accuracy check against the brute-force optimum
//! (§6.1's "100% prediction accuracy" experiment).
//!
//! # One control loop
//!
//! Every driver here is one observe -> decide -> execute -> record ->
//! learn epoch loop, at two degenerate corners of the control period:
//!
//! - **Synchronous rounds** (control period == round boundary): each
//!   epoch is one paper §4.2.2 round through [`Orchestrator::round`];
//!   [`Orchestrator::train`]/[`Orchestrator::train_full`] and
//!   [`Orchestrator::evaluate`] are thin configurations of the shared
//!   private `sync_epochs` driver (explore+learn vs greedy).
//! - **Open loop** ([`Orchestrator::evaluate_online`] /
//!   [`Orchestrator::train_online`]): epochs are `period_ms` slices of
//!   a stochastic arrival trace through the pausable DES control plane —
//!   at each control tick the live monitored state (background load
//!   merged with queue depths, under the drift schedule's conds) is
//!   re-encoded, the agent re-decides, and subsequent arrivals route
//!   under the new decision while requests in flight complete under the
//!   one that launched them. [`Orchestrator::evaluate_async`] is the
//!   single-epoch corner (control period = horizon), pinned bit-exact
//!   against the historical frozen-snapshot evaluation.

pub mod cache;

use std::sync::Arc;

use crate::agent::{bruteforce, Agent};
use crate::orchestrator::cache::{pack_down_mask, DecisionCache, DecisionKey};
use crate::metrics::{
    EpochRecord, LatencySummary, OnlineReport, RoundRecord, RunMetrics, TrafficMetrics,
};
use crate::monitor::{self, EncodedState, TopoState};
use crate::sim::admission::{self, AdmissionPolicy};
use crate::sim::des::{DesCore, DesOutcome};
use crate::sim::drift::{DriftSchedule, DriftSegment};
use crate::sim::telemetry::Recorder;
use crate::sim::workload::Request;
use crate::sim::{
    arrivals, run_sharded_open_loop, ArrivalProcess, Env, FaultPlan, FaultSchedule, ShardPlan,
    ShardedOutcome,
};
use crate::types::Decision;
use crate::util::pool::ThreadPool;
use crate::util::stats::Convergence;

/// The online control plane's knobs are the `[control]` config section;
/// re-exported here under the name the drivers use. The default is one
/// epoch spanning the horizon with online learning enabled;
/// [`Orchestrator::evaluate_async`] opts out of learning explicitly (a
/// frozen snapshot never learns).
pub use crate::config::ControlConfig as ControlCfg;

/// The ingress admission knobs are the `[admission]` config section;
/// re-exported like [`ControlCfg`]. The default is inactive (admit
/// everything, no deadlines) — bit-identical to the pre-admission engine.
pub use crate::config::AdmissionConfig as AdmissionCfg;

/// Bring the DES service/path tables in line with the drift segment in
/// force at `at_ms`: when its cond overrides differ from the installed
/// segment's, rebuild the physics state from the environment's background
/// snapshot and re-table the core — leaving requests in flight (and the
/// queues they occupy) untouched. No-op while the segment's conds are
/// unchanged (rate drift lives in the arrival trace, not the tables).
fn sync_drift_tables(
    env: &Env,
    drift: &DriftSchedule,
    at_ms: f64,
    seg: &mut DriftSegment,
    phys: &mut TopoState,
    core: &mut DesCore,
) {
    let now = *drift.at(at_ms);
    if (now.device_cond, now.edge_cond) != (seg.device_cond, seg.edge_cond) {
        *seg = now;
        *phys = env.state.clone();
        seg.apply_conds(phys);
        // Delta refill: only the (user, placement) rows whose inputs
        // actually changed are recomputed — bitwise identical to the full
        // `retable()` (property-pinned), and what keeps a cond-only drift
        // boundary from paying the whole users x models x placements bill.
        core.retable_delta(&env.model, phys);
    }
}

/// Training-curve point: (step, windowed average reward).
pub type CurvePoint = (usize, f64);

#[derive(Debug)]
pub struct TrainResult {
    pub steps: usize,
    pub converged_at: Option<usize>,
    /// Windowed average-reward curve (Fig. 6's y-axis).
    pub curve: Vec<CurvePoint>,
}

pub struct Orchestrator {
    pub env: Env,
    pub agent: Box<dyn Agent>,
    /// Optional flight recorder the next online run attaches to its DES
    /// core (request spans, admission verdicts, per-tick gauges, epoch
    /// marks). Taken for the duration of the run and put back flushed;
    /// None (the default) records nothing and is bitwise-transparent.
    pub recorder: Option<Recorder>,
    /// Event-queue implementation the online DES core runs on
    /// (`[perf] scheduler`). Heap is the reference; the wheel is
    /// property-pinned bitwise identical, so this only changes cost.
    pub scheduler: crate::sim::SchedulerKind,
    /// Timing-wheel bucket-width policy (`[perf] wheel_granularity`).
    /// Ignored on the heap; any mode is property-pinned bitwise identical
    /// to the heap, so this only changes calendar cost.
    pub wheel_granularity: crate::sim::WheelGranularity,
    /// Decision-memo capacity (`[perf] decision_cache`), entries; 0
    /// disables. Only frozen evaluations (`explore = false`, `learn =
    /// false`) consult the cache — a learning agent's decide is not pure —
    /// and hits are property-pinned bitwise identical to cache-off.
    pub decision_cache: usize,
    /// `[metrics] approx_threshold`: runs completing more than this many
    /// requests summarize latency through the bounded-memory histogram
    /// path of [`TrafficMetrics::from_outcome_with`]. 0 = always exact.
    pub metrics_approx_threshold: usize,
}

impl Orchestrator {
    pub fn new(env: Env, agent: Box<dyn Agent>) -> Orchestrator {
        Orchestrator {
            env,
            agent,
            recorder: None,
            scheduler: crate::sim::SchedulerKind::Heap,
            wheel_granularity: crate::sim::WheelGranularity::Span,
            decision_cache: crate::config::PerfConfig::DEFAULT_DECISION_CACHE,
            metrics_approx_threshold: 0,
        }
    }

    /// One orchestrated round (Fig. 4 steps 1-5): observe state, decide,
    /// execute, reward, learn.
    pub fn round(&mut self, explore: bool) -> RoundRecord {
        self.round_with(explore, None).0
    }

    /// [`Orchestrator::round`] with an optional pre-encoded state: round
    /// t's post-step encoding is round t+1's state, so the training and
    /// evaluation loops thread it back in instead of re-encoding — halving
    /// monitor encodes over a whole run. Callers must only pass an
    /// encoding produced by the immediately preceding round (the loops
    /// below hold `&mut self` across rounds, so nothing can mutate the
    /// environment in between); `None` encodes fresh, which is always
    /// correct.
    fn round_with(
        &mut self,
        explore: bool,
        cached: Option<EncodedState>,
    ) -> (RoundRecord, EncodedState) {
        let state = cached.unwrap_or_else(|| self.env.encoded());
        // The exploration rate that governed *this* decision (the learn()
        // below advances the agent's schedule).
        let epsilon = if explore { self.agent.epsilon() } else { 0.0 };
        let decision = self.agent.decide(&state, explore);
        let out = self.env.step(&decision);
        let next = self.env.encoded();
        if explore {
            self.agent.learn(&state, &decision, out.reward, &next);
        }
        let rec = RoundRecord {
            step: self.agent.steps(),
            decision,
            avg_response_ms: out.avg_ms,
            avg_accuracy: out.avg_accuracy,
            reward: out.reward,
            epsilon,
            response_ms: out.responses_ms,
        };
        (rec, next)
    }

    /// The synchronous-epoch driver both training and greedy evaluation
    /// run on (the "control period == round boundary" corner of the
    /// control loop): up to `epochs` rounds through
    /// [`Orchestrator::round_with`], threading each round's post-step
    /// encoding into the next (sound: this loop owns `&mut self` between
    /// rounds), handing every record to `sink`. `sink` returning false
    /// stops the loop — the convergence early-exit of
    /// [`Orchestrator::train`].
    fn sync_epochs(
        &mut self,
        epochs: usize,
        explore: bool,
        mut sink: impl FnMut(usize, &RoundRecord) -> bool,
    ) {
        let mut carry: Option<EncodedState> = None;
        for step in 0..epochs {
            let (rec, next) = self.round_with(explore, carry.take());
            carry = Some(next);
            if !sink(step, &rec) {
                break;
            }
        }
    }

    /// The one training loop: run up to `steps` exploring rounds, sample
    /// the windowed average-reward curve every `curve_every` rounds, and —
    /// when `stop_at_convergence` — break once the rolling-window mean of
    /// the reward is stable within 1% for the patience window (Table 11's
    /// stopping rule). [`Orchestrator::train`] and
    /// [`Orchestrator::train_full`] are the two calling conventions.
    fn train_loop(
        &mut self,
        steps: usize,
        curve_every: usize,
        stop_at_convergence: bool,
    ) -> TrainResult {
        let window = (steps / 100).clamp(10, 2000);
        let mut conv = Convergence::new(window, 0.01, 3);
        let mut curve = Vec::new();
        let mut acc = 0.0;
        let mut count = 0usize;
        self.sync_epochs(steps, true, |step, rec| {
            conv.push(rec.reward);
            acc += rec.reward;
            count += 1;
            if (step + 1) % curve_every.max(1) == 0 {
                curve.push((step + 1, acc / count as f64));
                acc = 0.0;
                count = 0;
            }
            !(stop_at_convergence && conv.is_converged() && step > 2 * window)
        });
        TrainResult { steps: self.agent.steps(), converged_at: conv.converged_at, curve }
    }

    /// Train until `max_steps` or convergence (rolling-window mean of the
    /// reward stable within 1% for `patience` windows). `curve_every`
    /// controls the sampling density of the returned curve.
    pub fn train(&mut self, max_steps: usize, curve_every: usize) -> TrainResult {
        self.train_loop(max_steps, curve_every, true)
    }

    /// Train for exactly `steps` rounds (full curves for Fig. 6/7).
    pub fn train_full(&mut self, steps: usize, curve_every: usize) -> TrainResult {
        self.train_loop(steps, curve_every, false)
    }

    /// Greedy evaluation over `rounds` (no exploration, no learning).
    pub fn evaluate(&mut self, rounds: usize) -> RunMetrics {
        let mut m = RunMetrics::new();
        self.sync_epochs(rounds, false, |_, rec| {
            m.push(rec);
            true
        });
        m
    }

    /// Asynchronous (open-loop) evaluation: score the greedy policy under
    /// stochastic arrivals instead of synchronous rounds.
    ///
    /// The agent's greedy decision at the current monitored state is
    /// installed as the routing policy, an arrival trace is generated from
    /// `process` over `horizon_ms`, and the DES core plays it through the
    /// per-node vCPU queues. The returned [`TrafficMetrics`] carry
    /// *per-request* response percentiles (p50/p95/p99) and throughput —
    /// the open-loop quality signal round averages cannot express.
    /// Deterministic for a fixed `seed` (trace and service noise both
    /// derive from it).
    ///
    /// This is the frozen-snapshot corner of the control loop: one epoch
    /// spanning the whole horizon, no drift. The integration suite pins
    /// it bitwise against the historical decide-once + `run_open_loop`
    /// path.
    pub fn evaluate_async(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
    ) -> TrafficMetrics {
        // Frozen snapshot by definition: one epoch, no drift, no learning
        // (explicitly off — the config default enables online learning
        // for the control-plane drivers, but a frozen evaluation must
        // leave the agent untouched).
        let frozen = ControlCfg { period_ms: f64::INFINITY, online_learning: false };
        self.evaluate_online(process, horizon_ms, seed, &frozen, &DriftSchedule::none())
            .metrics
    }

    /// Sharded open-loop evaluation for population scales the single
    /// event loop cannot hold: freeze the agent's greedy decision at the
    /// idle snapshot, then play the stochastic trace through the
    /// [`crate::sim::ShardedDes`] engine — one event loop per edge
    /// domain (run on `pool` when given), arrivals streamed per
    /// conservative time window instead of materialized, memory bounded
    /// by the live set. Rate-only `drift` applies inside the per-shard
    /// arrival streams; mid-trace re-decision and cond drift stay on
    /// [`Orchestrator::evaluate_online`]'s single-core control plane.
    ///
    /// The engine requires a domain-local decision (local / home-edge /
    /// cloud placements) and panics otherwise, like the direct
    /// [`crate::sim::run_sharded_open_loop`] entry point. Deterministic
    /// for a fixed `seed` (same `^ 0x5EED_DE5` noise-stream convention
    /// as the online path) and bitwise independent of shard count,
    /// window size, and worker pool.
    /// Fault injection is likewise a single-core-control-plane feature:
    /// the sharded engine has no timeout/retry lifecycle, so a non-empty
    /// `[faults]` schedule is rejected loudly instead of silently ignored.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate_sharded(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        drift: &DriftSchedule,
        faults: &FaultSchedule,
        plan: ShardPlan,
        pool: Option<&crate::util::pool::ThreadPool>,
    ) -> ShardedOutcome {
        assert!(
            faults.is_identity(),
            "the sharded engine does not support fault injection; \
             [faults] requires the single-core control plane (evaluate_online / \
             evaluate_chaos)"
        );
        self.env.reset_load();
        let enc = self.env.encoded();
        let decision = self.agent.decide(&enc, false);
        run_sharded_open_loop(
            &self.env.model,
            &self.env.state,
            &decision,
            process,
            horizon_ms,
            seed,
            seed ^ 0x5EED_DE5,
            drift,
            plan,
            pool,
        )
    }

    /// Online (control-plane) evaluation: play a stochastic arrival trace
    /// through the DES, pausing every `ctl.period_ms` of virtual time to
    /// re-encode the live monitored state — background load merged with
    /// per-node queue depths ([`monitor::overlay_live_load`]) under
    /// `drift`'s current link conditions — and let the agent re-decide.
    /// Arrivals route under the decision of their epoch; requests in
    /// flight complete under the decision that launched them. With
    /// `ctl.online_learning` the agent also `learn()`s each epoch's
    /// realized Eq. 4 reward (greedy decisions, no exploration): the
    /// paper's online-adaptation story under drift. The reward is
    /// SARSA-like — the realized cost while the decision was in force,
    /// including the drain of requests launched under the previous
    /// decision (see [`EpochRecord::reward`] for the rationale).
    ///
    /// Deterministic for a fixed `seed`; with the identity drift schedule
    /// and one epoch it reproduces [`Orchestrator::evaluate_async`]
    /// bitwise.
    pub fn evaluate_online(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        ctl: &ControlCfg,
        drift: &DriftSchedule,
    ) -> OnlineReport {
        self.evaluate_admission(process, horizon_ms, seed, ctl, drift, &AdmissionCfg::default())
    }

    /// [`Orchestrator::evaluate_online`] with a configured ingress
    /// admission policy: each arrival is judged at its arrival instant
    /// against the live queues (predicted completion from the memoized
    /// service tables + backlog + en-route admissions vs the stamped
    /// deadline) and may be shed, deferred to the next control tick, or
    /// degraded to a cheaper model before enqueueing. With the default
    /// (inactive) config this *is* `evaluate_online`, byte for byte.
    pub fn evaluate_admission(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        ctl: &ControlCfg,
        drift: &DriftSchedule,
        admission: &AdmissionCfg,
    ) -> OnlineReport {
        self.run_online(
            process,
            horizon_ms,
            seed,
            ctl.period_ms,
            false,
            ctl.online_learning,
            drift,
            admission,
            &FaultPlan::none(),
            &mut |_| None,
        )
    }

    /// [`Orchestrator::evaluate_admission`] under a fault plan: the DES
    /// injects the plan's node/link outages at their virtual-time
    /// boundaries, evicts attempts that exceed the per-attempt timeout,
    /// and re-admits per the retry policy — while the control plane
    /// observes the node-health mask ([`monitor::mask_down_nodes`]) so
    /// the agent re-routes around outages, and `learn()` prices each
    /// terminal failure like a shed arrival. With the empty plan this
    /// *is* `evaluate_admission`, byte for byte.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate_chaos(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        ctl: &ControlCfg,
        drift: &DriftSchedule,
        admission: &AdmissionCfg,
        faults: &FaultPlan,
    ) -> OnlineReport {
        self.run_online(
            process,
            horizon_ms,
            seed,
            ctl.period_ms,
            false,
            ctl.online_learning,
            drift,
            admission,
            faults,
            &mut |_| None,
        )
    }

    /// [`Orchestrator::evaluate_online`] with exploration on: epsilon-
    /// greedy decisions at each control tick plus online learning — the
    /// open-loop counterpart of [`Orchestrator::train`], for training
    /// directly against trace dynamics.
    pub fn train_online(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        period_ms: f64,
        drift: &DriftSchedule,
    ) -> OnlineReport {
        self.run_online(
            process,
            horizon_ms,
            seed,
            period_ms,
            true,
            true,
            drift,
            &AdmissionCfg::default(),
            &FaultPlan::none(),
            &mut |_| None,
        )
    }

    /// The open-loop control loop all online drivers share. `decide`
    /// overrides the agent when it returns Some (the drift experiment's
    /// per-epoch oracle); with the default `|_| None` every decision is
    /// the agent's.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_online(
        &mut self,
        process: ArrivalProcess,
        horizon_ms: f64,
        seed: u64,
        period_ms: f64,
        explore: bool,
        learn: bool,
        drift: &DriftSchedule,
        admission: &AdmissionCfg,
        faults: &FaultPlan,
        decide: &mut dyn FnMut(&TopoState) -> Option<Decision>,
    ) -> OnlineReport {
        let users = self.env.users();
        let mut trace = arrivals::schedule_with_drift(process, users, horizon_ms, seed, drift);
        let period = if period_ms.is_finite() && period_ms > 0.0 { period_ms } else { horizon_ms };

        let mut core = DesCore::with_scheduler(self.scheduler);
        core.set_wheel_granularity(self.wheel_granularity);
        let mut out = DesOutcome::default();
        // Decision memo: engaged only on frozen evaluations, where the
        // agent's decide is a pure zero-RNG function of the quantized
        // encoding (the key fully determines the feature vector) — a hit
        // replays the bit-identical decision. Exploring or learning runs
        // force capacity 0: epsilon draws and table updates make decide
        // impure, so those paths never consult the memo.
        let mut memo: DecisionCache<DecisionKey, Decision> =
            DecisionCache::new(if !explore && !learn { self.decision_cache } else { 0 });
        let policy_id = crate::config::ADMISSION_POLICIES
            .iter()
            .position(|&p| p == admission.policy)
            .unwrap_or(0) as u8;
        // Physics state: the background snapshot under the drift segment's
        // cond overrides. Live queue depths are *observation only* — the
        // DES models congestion as real queueing, so folding it back into
        // the service law would double-count it.
        let mut seg = *drift.at(0.0);
        let mut phys = self.env.state.clone();
        seg.apply_conds(&mut phys);
        core.install(&self.env.model, &phys);
        core.set_fault_plan(faults);
        // Policed ingress only when the user configured [admission]: the
        // default path must stay bitwise the pre-admission engine, and an
        // invalid config never reaches here (Config::load validates).
        let mut policy: Option<Box<dyn AdmissionPolicy>> = if admission.active() {
            admission::stamp_deadlines(
                &mut trace,
                &core,
                admission.deadline_ms,
                admission.slo_multiplier,
            );
            let mut p = admission.build().expect("admission config validated at load time");
            p.reset();
            Some(p)
        } else {
            None
        };
        let mut deferred: Vec<Request> = Vec::new();
        core.begin(seed ^ 0x5EED_DE5, &mut out);
        core.set_recorder(self.recorder.take());

        let mut epochs: Vec<EpochRecord> = Vec::new();
        let mut learn_steps = 0usize;
        // (state, decision, reward) of the epoch awaiting its next-state
        // encoding for learn(); None when the epoch saw no completions.
        let mut pending: Option<(EncodedState, Decision, f64)> = None;
        let mut cursor = 0usize;
        let mut t = 0.0f64;
        let mut epoch = 0usize;
        loop {
            let t_end = if t + period >= horizon_ms { horizon_ms } else { t + period };
            // The world drifts regardless of the controller: make sure the
            // tables match the segment in force at this tick before
            // observing (a boundary exactly at t must already be visible).
            sync_drift_tables(&self.env, drift, t, &mut seg, &mut phys, &mut core);
            // Sample the flight recorder's gauges at the same instant the
            // controller observes (no-op without a recorder).
            core.record_gauges(t);
            // Observe: live queue depths over the physics state.
            let obs = self.observe_live(&core, &phys);
            let enc = monitor::encode(&obs);
            if learn {
                if let Some((ps, pd, pr)) = pending.take() {
                    self.agent.learn(&ps, &pd, pr, &enc);
                    learn_steps += 1;
                }
            }
            let epsilon = if explore { self.agent.epsilon() } else { 0.0 };
            let decision = match decide(&obs) {
                Some(d) => d,
                None if memo.enabled() => {
                    let key = DecisionKey {
                        state_key: enc.key,
                        down_mask: if core.faults_active() {
                            pack_down_mask(core.node_down_mask())
                        } else {
                            0
                        },
                        policy_id,
                    };
                    match memo.get(&key) {
                        Some(d) => d,
                        None => {
                            let d = self.agent.decide(&enc, explore);
                            memo.put(key, d.clone());
                            d
                        }
                    }
                }
                None => self.agent.decide(&enc, explore),
            };
            let (shed0, defer0, degrade0, failed0) =
                (out.shed, out.deferrals, out.degraded, out.failed);
            // Requests deferred at an earlier tick are re-presented now,
            // under this epoch's decision and against the live backlog.
            if let Some(pol) = policy.as_mut() {
                if !deferred.is_empty() {
                    let batch = std::mem::take(&mut deferred);
                    core.admit_policed(&decision, &batch, t, &mut **pol, &mut deferred, &mut out);
                }
            }
            // Advance virtual time to the next control tick (final epoch:
            // drain everything, like the frozen evaluation), pausing at
            // every drift boundary on the way so cond changes are
            // physical at the time they happen — independent of the
            // control period. Arrivals are admitted per drift slice
            // (always under this epoch's decision) so each is routed with
            // the path overheads in force at its arrival time.
            let before = out.completed.len();
            let mut seg_t = t;
            loop {
                sync_drift_tables(&self.env, drift, seg_t, &mut seg, &mut phys, &mut core);
                let boundary = drift.next_boundary_after(seg_t);
                let stop = boundary.min(t_end);
                let next = cursor + trace[cursor..].partition_point(|r| r.arrival_ms < stop);
                match policy.as_mut() {
                    Some(pol) => core.admit_policed(
                        &decision,
                        &trace[cursor..next],
                        seg_t,
                        &mut **pol,
                        &mut deferred,
                        &mut out,
                    ),
                    None => core.admit(&decision, &trace[cursor..next]),
                }
                cursor = next;
                if t_end >= horizon_ms {
                    // final epoch: step through the remaining in-horizon
                    // boundaries first (arrivals are admitted per slice)
                    if boundary < t_end {
                        core.run_until(boundary, &mut out);
                        seg_t = boundary;
                        continue;
                    }
                    // Every arrival is admitted. Resolve outstanding
                    // deferrals at the horizon *before* the clock passes
                    // it — draining after a post-horizon drift boundary
                    // would inject joins behind the makespan and corrupt
                    // the backlog integrals.
                    if let Some(pol) = policy.as_mut() {
                        core.drain_deferred(
                            &decision,
                            horizon_ms,
                            &mut **pol,
                            &mut deferred,
                            &mut out,
                        );
                    }
                    // The world keeps drifting while the backlog drains:
                    // step through post-horizon boundaries so cond
                    // changes stay physical, then drain the heap.
                    let mut b = boundary;
                    while b.is_finite() {
                        core.run_until(b, &mut out);
                        sync_drift_tables(&self.env, drift, b, &mut seg, &mut phys, &mut core);
                        b = drift.next_boundary_after(b);
                    }
                    core.run_until(f64::INFINITY, &mut out);
                    break;
                } else if boundary < t_end {
                    core.run_until(boundary, &mut out);
                    seg_t = boundary;
                } else {
                    core.run_until(t_end, &mut out);
                    break;
                }
            }
            // Record the epoch from its realized completions (plus, under
            // an admission policy, the worst-case cost of what it shed —
            // learn() must see that rejecting work is not free).
            let responses: Vec<f64> =
                out.completed[before..].iter().map(|c| c.response_ms).collect();
            let summary = LatencySummary::of(&responses);
            let epoch_shed = out.shed - shed0;
            let epoch_degraded = out.degraded - degrade0;
            let epoch_failed = out.failed - failed0;
            // Shed and terminally-failed requests are priced identically:
            // either way a user got nothing, so learn() charges one
            // worst-case (`penalty_ms`) response per lost request.
            let epoch_lost = epoch_shed + epoch_failed;
            // Accuracy for Eq. 4: nominal until the ingress has overridden
            // any model this run — from then on the *realized* mean over
            // the epoch's served models, so a Degrade ingress is graded on
            // what it actually served even when degraded admissions drain
            // into a later epoch. Keying on realized degradation (not
            // merely an active policy) keeps admit_all / shed / defer runs
            // bitwise on the nominal path — what lets explicit
            // `--admission admit_all` stay byte-identical to the
            // pre-admission engine.
            let accuracy = if out.degraded > 0 && !responses.is_empty() {
                let t5 = crate::models::top5_table();
                out.completed[before..]
                    .iter()
                    .map(|c| t5[c.action.model.index()])
                    .sum::<f64>()
                    / responses.len() as f64
            } else {
                self.env.accuracy_of(&decision)
            };
            let reward = if responses.is_empty() && epoch_lost == 0 {
                0.0
            } else {
                let mean_ms = if epoch_lost == 0 {
                    summary.mean_ms
                } else {
                    (responses.iter().sum::<f64>() + epoch_lost as f64 * self.env.penalty_ms())
                        / (responses.len() + epoch_lost) as f64
                };
                self.env.reward(mean_ms, accuracy)
            };
            pending = if responses.is_empty() && epoch_lost == 0 {
                None
            } else {
                Some((enc, decision.clone(), reward))
            };
            epochs.push(EpochRecord {
                epoch,
                start_ms: t,
                end_ms: t_end,
                decision,
                epsilon,
                requests: responses.len(),
                response: summary,
                reward,
                shed: epoch_shed,
                deferrals: out.deferrals - defer0,
                degraded: epoch_degraded,
                deadline_misses: out.completed[before..]
                    .iter()
                    .filter(|c| !c.on_time())
                    .count(),
                failed: epoch_failed,
            });
            core.record_epoch(t_end, epoch);
            epoch += 1;
            t = t_end;
            if t >= horizon_ms {
                break;
            }
        }
        // Close out the last epoch's learning against the drained state.
        if learn {
            if let Some((ps, pd, pr)) = pending.take() {
                let obs = self.observe_live(&core, &phys);
                let enc = monitor::encode(&obs);
                self.agent.learn(&ps, &pd, pr, &enc);
                learn_steps += 1;
            }
        }
        core.finalize(&mut out);
        out.perf.cache_hits = memo.hits();
        out.perf.cache_misses = memo.misses();
        if let Some(mut rec) = core.take_recorder() {
            rec.flush();
            self.recorder = Some(rec);
        }
        out.horizon_ms = horizon_ms;
        let last_decision =
            epochs.last().map(|e| e.decision.clone()).expect("at least one epoch");
        let metrics =
            TrafficMetrics::from_outcome_with(&last_decision, &out, self.metrics_approx_threshold);
        OnlineReport { epochs, metrics, outcome: out, learn_steps }
    }

    /// The control plane's mid-trace observation: the physics state (background
    /// load + drift conds) with each compute node's live queue-derived
    /// utilization max-merged in — and, under an active fault plan, down
    /// nodes pinned to the top CPU level so the policy routes around
    /// them (a no-op without faults, keeping fault-free runs bitwise).
    fn observe_live(&self, core: &DesCore, phys: &TopoState) -> TopoState {
        let load: Vec<f64> =
            (0..core.num_compute_nodes()).map(|i| core.utilization(i)).collect();
        let mut obs = monitor::overlay_live_load(phys, &load);
        if core.faults_active() {
            monitor::mask_down_nodes(&mut obs, core.node_down_mask());
        }
        obs
    }

    /// The representative greedy decision at the idle system state —
    /// what the paper's Tables 8/9/10 print per scenario.
    pub fn representative_decision(&mut self) -> (Decision, f64, f64) {
        self.env.reset_load();
        let state = self.env.encoded();
        let decision = self.agent.decide(&state, false);
        let avg = self.env.expected_avg_ms(&decision);
        let acc = self.env.accuracy_of(&decision);
        (decision, avg, acc)
    }

    /// Fraction of greedy decisions matching the brute-force optimum's
    /// objective value over `trials` evolving states (§6.1: the paper
    /// reports 100% after convergence). Matching is by expected average
    /// response (distinct decisions can tie exactly).
    ///
    /// Trials where the oracle declines to score (instances past its
    /// enumeration budget, see [`bruteforce::optimal`]) are skipped rather
    /// than counted as misses; the returned rate is over scored trials
    /// only, and 0.0 — never NaN — when nothing could be scored. Callers
    /// that must distinguish "0% hit-rate" from "nothing scorable" use
    /// [`Orchestrator::prediction_accuracy_scored`].
    pub fn prediction_accuracy(&mut self, trials: usize, tol: f64) -> f64 {
        self.prediction_accuracy_scored(trials, tol).0
    }

    /// [`Orchestrator::prediction_accuracy`] plus how many of the
    /// `trials` the oracle actually scored — 0 scored means the rate
    /// carries no information (the instance is past the oracle budget).
    ///
    /// The rollout is serial (each trial's state depends on the previous
    /// decision's execution), but the expensive part — the brute-force
    /// oracle — is a pure function of (model, state snapshot), so the
    /// per-trial oracle calls fan out across a thread pool and come back
    /// in trial order: results are bit-identical to the serial loop.
    pub fn prediction_accuracy_scored(&mut self, trials: usize, tol: f64) -> (f64, usize) {
        if trials == 0 {
            return (0.0, 0);
        }
        // Phase 1 (serial): roll the environment forward exactly as the
        // sequential version did, snapshotting each trial's background
        // state for the oracle.
        let mut snaps: Vec<(f64, bool, TopoState)> = Vec::with_capacity(trials);
        for _ in 0..trials {
            let state = self.env.encoded();
            let decision = self.agent.decide(&state, false);
            let ours = self.env.expected_avg_ms(&decision);
            let acc_ok = self.env.accuracy_of(&decision) > self.env.threshold;
            snaps.push((ours, acc_ok, self.env.state.clone()));
            // advance dynamics by actually executing the chosen decision
            self.env.step(&decision);
        }
        // Phase 2 (parallel): score every snapshot against the optimum.
        let model = Arc::new(self.env.model.clone());
        let threshold = self.env.threshold;
        let workers =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(trials);
        let pool = ThreadPool::new(workers, "oracle");
        let verdicts: Vec<Option<bool>> =
            pool.map_indexed(snaps, move |_, (ours, acc_ok, snap)| {
                bruteforce::optimal_for(model.as_ref(), &snap, threshold)
                    .map(|(_, best)| acc_ok && (ours - best) / best <= tol)
            });
        let scored = verdicts.iter().filter(|v| v.is_some()).count();
        if scored == 0 {
            return (0.0, 0);
        }
        let hits = verdicts.iter().filter(|v| **v == Some(true)).count();
        (hits as f64 / scored as f64, scored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::baseline::FixedAgent;
    use crate::agent::qlearning::QTableAgent;
    use crate::agent::ActionSet;
    use crate::config::{Algo, Calibration, Hyper, Scenario};
    use crate::types::{AccuracyConstraint, Tier};

    fn env(users: usize, c: AccuracyConstraint) -> Env {
        Env::new(Scenario::exp_a(users), Calibration::default(), c, 11)
    }

    fn ql(users: usize) -> Box<dyn Agent> {
        Box::new(QTableAgent::new(
            users,
            Hyper::paper_defaults(Algo::QLearning, users),
            ActionSet::full(),
            13,
        ))
    }

    #[test]
    fn evaluate_sharded_is_deterministic_and_conserves_requests() {
        let users = 4;
        let run = |shards: usize| {
            let mut o = Orchestrator::new(
                env(users, AccuracyConstraint::Max),
                Box::new(FixedAgent::new(Tier::Local, users)),
            );
            o.evaluate_sharded(
                ArrivalProcess::Poisson { rate_per_s: 4.0 },
                6_000.0,
                17,
                &DriftSchedule::none(),
                &FaultSchedule::none(),
                ShardPlan { shards, ..Default::default() },
                None,
            )
        };
        let a = run(1);
        assert!(a.conservation_ok);
        assert!(a.offered > 50, "workload too small: {}", a.offered);
        assert_eq!(a.summary.completed, a.offered, "final drain completes everything");
        // same seed -> same trace; the single-edge env has one domain, so
        // shards=1 is the only admissible plan and reruns pin bitwise
        let b = run(1);
        assert_eq!(a.summary.digest, b.summary.digest);
        assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
    }

    #[test]
    #[should_panic(expected = "single-core control plane")]
    fn evaluate_sharded_rejects_fault_schedules() {
        let users = 2;
        let mut o = Orchestrator::new(
            env(users, AccuracyConstraint::Max),
            Box::new(FixedAgent::new(Tier::Local, users)),
        );
        let faults = FaultSchedule::parse("1000:edge0=down").unwrap();
        let _ = o.evaluate_sharded(
            ArrivalProcess::Poisson { rate_per_s: 1.0 },
            2_000.0,
            7,
            &DriftSchedule::none(),
            &faults,
            ShardPlan { shards: 1, ..Default::default() },
            None,
        );
    }

    #[test]
    fn online_faults_reroute_and_price_failures() {
        // edge0 dies mid-trace and never recovers. A failover policy with
        // per-attempt timeouts must rescue strictly more requests than
        // retry-none, and the report's failure taxonomy must be coherent.
        let users = 3;
        let process = crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.0 };
        let ctl = ControlCfg { period_ms: 2_500.0, online_learning: false };
        let run = |plan: &FaultPlan| {
            let mut o = Orchestrator::new(
                env(users, AccuracyConstraint::Max),
                Box::new(FixedAgent::new(Tier::Edge(0), users)),
            );
            o.env.freeze();
            o.evaluate_chaos(
                process,
                20_000.0,
                21,
                &ctl,
                &crate::sim::DriftSchedule::none(),
                &AdmissionCfg::default(),
                plan,
            )
        };
        // empty plan reproduces evaluate_admission byte for byte
        let healthy = run(&FaultPlan::none());
        assert_eq!(healthy.metrics.failed, 0);
        assert_eq!(healthy.metrics.retries, 0);
        assert_eq!(healthy.metrics.availability, 1.0);

        let schedule = FaultSchedule::parse("5000:edge0=down").unwrap();
        let none_plan = FaultPlan {
            schedule: schedule.clone(),
            retry: crate::sim::RetryPolicy::None,
            timeout_ms: 1_500.0,
        };
        let failover_plan = FaultPlan {
            schedule,
            retry: crate::sim::RetryPolicy::Failover { budget: 3, base_ms: 50.0 },
            timeout_ms: 1_500.0,
        };
        let abandoned = run(&none_plan);
        let rescued = run(&failover_plan);
        assert!(abandoned.metrics.failed > 0, "outage must kill unprotected work");
        assert_eq!(abandoned.metrics.retries, 0);
        assert!(abandoned.metrics.availability < 1.0);
        assert!(rescued.metrics.retries > 0);
        assert!(rescued.metrics.failovers > 0, "re-admissions must re-route");
        assert!(
            rescued.metrics.requests > abandoned.metrics.requests,
            "failover must complete more: {} !> {}",
            rescued.metrics.requests,
            abandoned.metrics.requests
        );
        // epoch records carry the failures the reward priced
        let failed_in_epochs: usize = abandoned.epochs.iter().map(|e| e.failed).sum();
        assert_eq!(failed_in_epochs, abandoned.metrics.failed);
    }

    #[test]
    fn round_records_are_consistent() {
        let mut o = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        let rec = o.round(true);
        assert_eq!(rec.response_ms.len(), 2);
        assert!(rec.avg_response_ms > 0.0);
        assert_eq!(o.agent.steps(), 1);
    }

    #[test]
    fn training_improves_over_random() {
        let mut o = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        o.env.freeze(); // single state: tabular convergence is exact
        let before = o.evaluate(50).response.mean();
        let _ = o.train_full(15_000, 5000);
        let after = o.evaluate(50).response.mean();
        assert!(
            after < before,
            "training should reduce avg response: {after} !< {before}"
        );
        // trained policy within 40% of the brute-force optimum (the
        // factored learner with lr 0.9 and shared rewards bounces between
        // near-equivalent smallest models; the experiment drivers use the
        // oracle fallback for table-exact decisions)
        o.env.reset_load();
        let (_, best) = bruteforce::optimal(&o.env, o.env.threshold).unwrap();
        let (_, ours, _) = o.representative_decision();
        assert!(ours <= best * 1.4, "ours={ours} best={best}");
    }

    #[test]
    fn fixed_agent_evaluation_matches_anchor() {
        let users = 5;
        let mut o = Orchestrator::new(
            env(users, AccuracyConstraint::Max),
            Box::new(FixedAgent::new(Tier::Local, users)),
        );
        o.env.freeze(); // idle background: the Fig 5 anchor setting
        let m = o.evaluate(20).response.mean();
        assert!((m - 459.0).abs() < 20.0, "device-only avg {m}");
    }

    #[test]
    fn evaluation_does_not_learn() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o.evaluate(10);
        assert_eq!(o.agent.steps(), 0);
    }

    #[test]
    fn round_records_surface_real_epsilon() {
        let users = 2;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        let hyper = crate::config::Hyper::paper_defaults(
            crate::config::Algo::QLearning,
            users,
        );
        // first exploring round sees the schedule's step-0 value (1.0)
        let rec = o.round(true);
        assert_eq!(rec.epsilon, hyper.epsilon_at(0));
        // subsequent rounds track the decaying schedule, not NaN
        for step in 1..20 {
            let rec = o.round(true);
            assert!(rec.epsilon.is_finite());
            assert_eq!(rec.epsilon, hyper.epsilon_at(step));
        }
        // greedy evaluation reports zero exploration
        assert_eq!(o.round(false).epsilon, 0.0);
    }

    #[test]
    fn async_evaluation_reports_percentiles_and_throughput() {
        let users = 3;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        o.env.freeze();
        o.env.reset_load();
        let m = o.evaluate_async(
            crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.0 },
            10_000.0,
            3,
        );
        assert!(m.requests > 10, "requests {}", m.requests);
        assert!(m.response.p50_ms > 0.0);
        assert!(m.response.p50_ms <= m.response.p95_ms);
        assert!(m.response.p95_ms <= m.response.p99_ms);
        assert!(m.throughput_rps > 0.0);
        assert_eq!(m.decision.n_users(), users);
    }

    #[test]
    fn evaluate_async_pins_frozen_snapshot_bitwise() {
        // The collapsed driver's single-epoch corner must reproduce the
        // historical decide-once + run_open_loop evaluation bit-for-bit.
        let users = 3;
        let mk = || {
            let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
            let _ = o.train_full(300, 300); // nontrivial policy + env rng state
            o
        };
        let mut a = mk();
        let mut b = mk();
        let process = crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.5 };
        let got = a.evaluate_async(process, 8_000.0, 9);

        // the historical frozen-snapshot path, restated verbatim
        let state = b.env.encoded();
        let decision = b.agent.decide(&state, false);
        let trace = crate::sim::arrivals::schedule(process, users, 8_000.0, 9);
        let outcome = b.env.open_loop(&decision, &trace, 8_000.0, 9 ^ 0x5EED_DE5);
        let want = TrafficMetrics::from_outcome(&decision, &outcome);
        assert!(got.requests > 0, "trace must be non-trivial");
        assert_eq!(got, want);

        // explicit single-epoch evaluate_online is the same thing
        let mut c = mk();
        let frozen = ControlCfg { period_ms: f64::INFINITY, online_learning: false };
        let rep = c.evaluate_online(
            process,
            8_000.0,
            9,
            &frozen,
            &crate::sim::DriftSchedule::none(),
        );
        assert_eq!(rep.epochs.len(), 1);
        assert_eq!(rep.metrics, want);
        assert_eq!(rep.learn_steps, 0);
        // the default config learns online (single final update here) but
        // the realized trace — and therefore the metrics — are identical:
        // learning happens strictly after each epoch's physics
        let mut d = mk();
        let rep2 = d.evaluate_online(
            process,
            8_000.0,
            9,
            &ControlCfg::default(),
            &crate::sim::DriftSchedule::none(),
        );
        assert_eq!(rep2.metrics, want);
        assert_eq!(rep2.learn_steps, 1);
        assert_eq!(d.agent.steps(), 300 + 1);
    }

    #[test]
    fn online_control_loop_reports_epochs_and_learns() {
        let users = 2;
        let process = crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.0 };
        let none = crate::sim::DriftSchedule::none();

        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        o.env.freeze();
        let ctl = ControlCfg { period_ms: 2_000.0, online_learning: true };
        let rep = o.evaluate_online(process, 10_000.0, 5, &ctl, &none);
        assert_eq!(rep.epochs.len(), 5);
        for (k, e) in rep.epochs.iter().enumerate() {
            assert_eq!(e.epoch, k);
            assert!((e.start_ms - k as f64 * 2_000.0).abs() < 1e-9);
            assert!(e.end_ms > e.start_ms);
            assert_eq!(e.epsilon, 0.0, "evaluation decides greedily");
        }
        assert!((rep.epochs.last().unwrap().end_ms - 10_000.0).abs() < 1e-9);
        // every completion is attributed to exactly one epoch
        let per_epoch: usize = rep.epochs.iter().map(|e| e.requests).sum();
        assert_eq!(per_epoch, rep.metrics.requests);
        assert!(rep.metrics.requests > 0);
        // online learning really advanced the agent
        assert!(rep.learn_steps >= 1);
        assert_eq!(o.agent.steps(), rep.learn_steps);

        // with learning off the agent is untouched
        let mut o2 = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        o2.env.freeze();
        let ctl_off = ControlCfg { period_ms: 2_000.0, online_learning: false };
        let rep2 = o2.evaluate_online(process, 10_000.0, 5, &ctl_off, &none);
        assert_eq!(rep2.learn_steps, 0);
        assert_eq!(o2.agent.steps(), 0);
    }

    #[test]
    fn fixed_policy_control_ticks_do_not_perturb_physics() {
        // A policy that never changes must see (numerically) the same
        // trace outcome whether the clock pauses 1 or many times.
        use crate::agent::baseline::FixedAgent;
        let users = 4;
        let process = crate::sim::ArrivalProcess::Poisson { rate_per_s: 1.2 };
        let none = crate::sim::DriftSchedule::none();
        let run = |period: f64| {
            let mut o = Orchestrator::new(
                env(users, AccuracyConstraint::Max),
                Box::new(FixedAgent::new(Tier::Edge(0), users)),
            );
            o.env.freeze();
            let ctl = ControlCfg { period_ms: period, online_learning: false };
            o.evaluate_online(process, 12_000.0, 17, &ctl, &none)
        };
        let single = run(f64::INFINITY);
        let ticked = run(1_500.0);
        assert_eq!(ticked.epochs.len(), 8);
        assert_eq!(single.metrics.requests, ticked.metrics.requests);
        assert!((single.metrics.makespan_ms - ticked.metrics.makespan_ms).abs() < 1e-9);
        assert!(
            (single.metrics.response.p95_ms - ticked.metrics.response.p95_ms).abs() < 1e-9
        );
        assert_eq!(ticked.decision_changes(), 0);
    }

    #[test]
    fn prediction_accuracy_skips_declined_oracle_and_never_nans() {
        // 8 users: past the oracle's enumeration budget, every trial is
        // declined -> 0.0 over zero scored trials, not NaN.
        let users = 8;
        let mut o = Orchestrator::new(env(users, AccuracyConstraint::Min), ql(users));
        let acc = o.prediction_accuracy(3, 0.02);
        assert_eq!(acc, 0.0);
        assert!(acc.is_finite());
        // the scored count disambiguates "0% hit-rate" from "unscorable"
        assert_eq!(o.prediction_accuracy_scored(3, 0.02), (0.0, 0));
        // zero trials is also defined
        let mut o2 = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        assert_eq!(o2.prediction_accuracy(0, 0.02), 0.0);
    }

    #[test]
    fn train_full_runs_exact_budget_train_may_stop_early() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        let full = o.train_full(500, 100);
        assert_eq!(full.steps, 500);
        assert_eq!(full.curve.len(), 5);
        // `train` shares the loop but may stop at convergence
        let mut o2 = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o2.env.freeze();
        let early = o2.train(20_000, 1000);
        assert!(early.steps <= 20_000);
        if let Some(at) = early.converged_at {
            assert!(at <= early.steps);
        }
    }

    #[test]
    fn cached_state_threading_matches_uncached_rounds() {
        // train_loop/evaluate reuse round t's post-step encoding as round
        // t+1's state; with identical seeds that must be behaviorally
        // indistinguishable from re-encoding every round (encode is pure).
        let mut a = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        let mut b = Orchestrator::new(env(2, AccuracyConstraint::Min), ql(2));
        // a: uncached public rounds; b: the cached training loop
        let ra: Vec<f64> = (0..300).map(|_| a.round(true).reward).collect();
        let _ = b.train_full(300, 300);
        assert_eq!(a.agent.steps(), b.agent.steps());
        // identical value functions -> identical greedy trajectories, and
        // identical env rng streams -> bit-equal rewards from here on
        for _ in 0..5 {
            let x = a.round(false);
            let y = b.round(false);
            assert_eq!(x.decision, y.decision);
            assert_eq!(x.reward.to_bits(), y.reward.to_bits());
        }
        assert!(ra.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn trained_agent_predicts_optimum_frozen_env() {
        let mut o = Orchestrator::new(env(1, AccuracyConstraint::Min), ql(1));
        o.env.freeze();
        let _ = o.train_full(3000, 1000);
        let acc = o.prediction_accuracy(10, 0.02);
        assert!(acc >= 0.9, "prediction accuracy {acc}");
    }
}
