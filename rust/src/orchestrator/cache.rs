//! Control-plane decision memo: a bounded, deterministic LRU.
//!
//! The per-tick hot path of [`crate::orchestrator::Orchestrator::run_online`]
//! is `observe_live → encode → decide`. Once an agent is frozen
//! (`explore = false`, `learn = false`) its `decide` is a *pure* function
//! of the quantized [`crate::monitor::EncodedState`] key — the greedy arm
//! is read straight from the learned tables with **zero RNG draws** — so
//! memoizing it returns the bit-identical decision the agent would have
//! recomputed. The same holds for the oracle anchors: `optimal_for` is a
//! pure sweep over the (continuous) state, so keying on an exact bit-level
//! fingerprint of that state memoizes it soundly. `tests/property_cache.rs`
//! pins cache-on == cache-off bitwise across drift × admission × faults.
//!
//! The LRU is dependency-free and deterministic: a `HashMap` plus a
//! logical stamp clock, with an O(capacity) oldest-stamp scan on eviction.
//! Stamps are assigned in call order, so which entry gets evicted never
//! depends on hash iteration order — repeat runs evict identically.

use std::collections::HashMap;
use std::hash::Hash;

/// Cache key for a memoized per-tick agent decision: the quantized state
/// key ([`crate::monitor::EncodedState::key`]), the packed node down-mask
/// the decision closure saw, and the admission-policy id the run was
/// configured with. Two ticks agreeing on all three are indistinguishable
/// to a frozen agent, so they must produce the same decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecisionKey {
    /// Quantized-state radix key from the monitor encoding.
    pub state_key: u64,
    /// Node health bitmask (bit i = node i down) at decision time.
    pub down_mask: u64,
    /// Index into [`crate::config::ADMISSION_POLICIES`].
    pub policy_id: u8,
}

/// Bounded deterministic LRU memo for pure control-plane functions.
///
/// Generic over the key so the same structure serves both the quantized
/// agent memo ([`DecisionKey`]) and the oracle's exact state-fingerprint
/// memo (`u64`). `capacity == 0` disables the cache entirely: `get`
/// always misses and `put` is a no-op, which keeps the off path free of
/// even bookkeeping.
#[derive(Debug, Clone)]
pub struct DecisionCache<K: Eq + Hash + Clone, V: Clone> {
    map: HashMap<K, (u64, V)>,
    /// Logical access clock — bumped on every get/put touch.
    clock: u64,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl<K: Eq + Hash + Clone, V: Clone> DecisionCache<K, V> {
    /// A cache holding at most `capacity` entries (0 = disabled).
    pub fn new(capacity: usize) -> Self {
        DecisionCache {
            map: HashMap::with_capacity(capacity.min(4096)),
            clock: 0,
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Whether the cache can ever store anything.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        if self.capacity == 0 {
            self.misses += 1;
            return None;
        }
        self.clock += 1;
        match self.map.get_mut(key) {
            Some((stamp, v)) => {
                *stamp = self.clock;
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) `key → value`, evicting the least-recently
    /// touched entry when full. Eviction scans stamps, not hash order, so
    /// it is deterministic across runs.
    pub fn put(&mut self, key: K, value: V) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        if !self.map.contains_key(&key) && self.map.len() >= self.capacity {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (self.clock, value));
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lookups served from the memo since construction (or `reset_stats`).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to a fresh computation.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Zero the hit/miss counters (entries are kept) — one evaluation's
    /// counters must not leak into the next run's `DesOutcome`.
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Drop every entry and zero the counters.
    pub fn clear(&mut self) {
        self.map.clear();
        self.clock = 0;
        self.hits = 0;
        self.misses = 0;
    }
}

/// Pack the per-node down flags into the [`DecisionKey::down_mask`] bit
/// field (bit i = node i down). Node counts beyond 64 saturate into the
/// top bit rather than silently aliasing distinct masks.
pub fn pack_down_mask(down: &[bool]) -> u64 {
    let mut mask = 0u64;
    for (i, &d) in down.iter().enumerate() {
        if d {
            mask |= 1u64 << i.min(63);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_returns_identical_value_and_counts() {
        let mut c: DecisionCache<DecisionKey, Vec<u8>> = DecisionCache::new(8);
        let k = DecisionKey { state_key: 42, down_mask: 0b10, policy_id: 1 };
        assert_eq!(c.get(&k), None);
        c.put(k, vec![3, 1, 4]);
        assert_eq!(c.get(&k), Some(vec![3, 1, 4]));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn evicts_least_recently_touched_deterministically() {
        let mut c: DecisionCache<u64, u64> = DecisionCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        assert_eq!(c.get(&1), Some(10)); // refresh 1 → 2 is now oldest
        c.put(3, 30);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c: DecisionCache<u64, u64> = DecisionCache::new(0);
        assert!(!c.enabled());
        c.put(1, 10);
        assert_eq!(c.get(&1), None);
        assert!(c.is_empty());
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 2);
    }

    #[test]
    fn distinct_masks_and_policies_do_not_alias() {
        let mut c: DecisionCache<DecisionKey, u32> = DecisionCache::new(8);
        let a = DecisionKey { state_key: 7, down_mask: 0, policy_id: 0 };
        let b = DecisionKey { state_key: 7, down_mask: 1, policy_id: 0 };
        let d = DecisionKey { state_key: 7, down_mask: 0, policy_id: 2 };
        c.put(a, 1);
        c.put(b, 2);
        c.put(d, 3);
        assert_eq!(c.get(&a), Some(1));
        assert_eq!(c.get(&b), Some(2));
        assert_eq!(c.get(&d), Some(3));
    }

    #[test]
    fn pack_down_mask_sets_bits() {
        assert_eq!(pack_down_mask(&[]), 0);
        assert_eq!(pack_down_mask(&[false, true, false, true]), 0b1010);
    }

    #[test]
    fn reset_stats_keeps_entries() {
        let mut c: DecisionCache<u64, u64> = DecisionCache::new(4);
        c.put(1, 10);
        let _ = c.get(&1);
        c.reset_stats();
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 0);
        assert_eq!(c.get(&1), Some(10));
    }
}
