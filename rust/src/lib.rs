//! # EECO — End-Edge-Cloud Orchestrator
//!
//! Production-shaped reproduction of *"Online Learning for Orchestration of
//! Inference in Multi-User End-Edge-Cloud Networks"* (Shahhosseini et al.,
//! 2022): an online reinforcement-learning orchestrator that jointly picks
//! computation offloading (local / edge / cloud) and DL model selection
//! (MobileNetV1 d0-d7) per end device to minimize average response time
//! under an average-accuracy constraint.
//!
//! Three-layer architecture (DESIGN.md §1): this Rust crate is Layer 3 —
//! the coordinator, simulator, RL agents and serving path. Layers 2 (JAX
//! graphs) and 1 (Pallas kernels) live in `python/compile/` and reach this
//! crate only as AOT-compiled HLO-text artifacts executed via PJRT.

pub mod config;
pub mod models;
pub mod types;
pub mod util;

pub mod cluster;
pub mod coordinator;
pub mod metrics;
pub mod monitor;
pub mod network;
pub mod orchestrator;
pub mod runtime;
pub mod sim;

pub mod agent;
pub mod experiments;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{Algo, Calibration, Config, Hyper, Mode, Scenario, TrafficConfig};
    pub use crate::sim::ArrivalProcess;
    pub use crate::models::{info as model_info, top5_table, CATALOG};
    pub use crate::types::{
        AccuracyConstraint, Action, Decision, ModelId, NetCond, NodeSpec, Placement, Tier,
        Topology, ACTIONS_PER_DEVICE, NUM_MODELS,
    };
    pub use crate::util::rng::Rng;
}
