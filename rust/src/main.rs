//! `eeco` — CLI entrypoint for the End-Edge-Cloud Orchestrator.
//!
//! Subcommands:
//!   eeco experiment <id|all> [--users N] [--scenario exp-a] [--steps K]
//!       regenerate a paper figure/table (see DESIGN.md §5)
//!   eeco train [--algo ql|dqn|sota] [--users N] [--constraint 85]
//!       train an agent and report convergence + final policy
//!   eeco serve [--users N] [--rounds R] [--constraint max]
//!       measured-mode serving: real PJRT inference through the
//!       router/batcher path, latency breakdown per request
//!   eeco calibrate
//!       measure per-model PJRT compute times (feeds the latency model)
//!   eeco info
//!       print catalog, scenario and artifact summary

use anyhow::{anyhow, Result};

use eeco::agent::bruteforce;
use eeco::config::{Config, Mode};
use eeco::coordinator::{serve_round, serve_trace, Router, ServeConfig};
use eeco::experiments::{self, ExpCtx};
use eeco::metrics::render_table;
use eeco::orchestrator::Orchestrator;
use eeco::prelude::*;
use eeco::runtime::SharedRuntime;
use eeco::sim::{Arrival, WorkloadGen};
use eeco::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg = Config::load(args).map_err(|e| anyhow!(e))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if cfg.topology.edges_min != cfg.topology.edges_max && cmd != "experiment" {
        return Err(anyhow!(
            "--edges {}..{} is a sweep range (for `experiment multi_edge`); `{cmd}` needs a \
             single edge count",
            cfg.topology.edges_min,
            cfg.topology.edges_max
        ));
    }
    // [admission] drives the control-plane experiments; anywhere else it
    // would be silently ignored, which the section's strict-validation
    // stance forbids — fail safe: reject unless the target is known to
    // honor it (no command allowlist to fall out of sync with the
    // dispatch below).
    if cfg.admission.active() {
        let exp = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
        let honored = cmd == "experiment" && matches!(exp, "drift" | "overload" | "fleet");
        if !honored {
            let target =
                if cmd == "experiment" { format!("experiment {exp}") } else { cmd.to_string() };
            let effect = if exp == "all" {
                "mixes policed (drift, overload, fleet) and silently unpoliced legs"
            } else {
                "would run unpoliced"
            };
            return Err(anyhow!(
                "--admission / [admission] is honored by `experiment drift`, `experiment \
                 overload` and `experiment fleet` only; `{target}` {effect} — drop the flag \
                 or run those experiments directly"
            ));
        }
    }
    // [faults]/[retry] follow the same fail-safe stance: honored by
    // `experiment drift` only (the chaos matrix builds its own fault
    // plans and ignores the sections), rejected anywhere they would be
    // silently dropped.
    if cfg.faults.active() || cfg.retry.explicit || cfg.retry.timeout_ms > 0.0 {
        let exp = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
        let honored = cmd == "experiment" && exp == "drift";
        if !honored {
            let target =
                if cmd == "experiment" { format!("experiment {exp}") } else { cmd.to_string() };
            return Err(anyhow!(
                "--faults / --retry / --retry-timeout ([faults]/[retry]) are honored by \
                 `experiment drift` only; `experiment chaos` sweeps its own fault matrix and \
                 `{target}` would silently run fault-free — drop the flags or run `experiment \
                 drift`"
            ));
        }
    }
    match cmd {
        "experiment" => cmd_experiment(args, cfg),
        "train" => cmd_train(args, cfg),
        "serve" => cmd_serve(args, cfg),
        "calibrate" => cmd_calibrate(cfg),
        "info" => cmd_info(cfg),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "eeco — online-learning orchestration of DL inference in end-edge-cloud networks

USAGE: eeco <command> [options]

COMMANDS:
  experiment <id|all>   regenerate paper figures/tables ({ids})
  train                 train an RL agent (--algo ql|dqn|sota, --users N,
                        --constraint min|80|85|89|max, --steps K, --scenario exp-a..d)
  serve                 measured-mode serving over PJRT (--rounds R, or
                        --trace to play the [traffic] arrival schedule
                        through the virtual-clock dynamic batcher)
  calibrate             measure per-model compute times on this host
  info                  print model catalog + artifact summary

OPTIONS (global): --users N  --scenario exp-a  --seed S  --artifacts DIR
                  --config FILE  --mode sim|measured
OPTIONS (topology): --edges K | --edges A..B   number of edge nodes the
                  network shards over (range form drives `experiment
                  multi_edge`; default 1 = the paper's network)
OPTIONS (traffic): --arrival sync|poisson|mmpp  --rate R  --horizon-ms H
                  (open-loop DES evaluation; see `experiment traffic_sweep`)
OPTIONS (control): --control-period MS   pause the open-loop trace every MS
                  of virtual time to re-encode live state and re-decide
                  (unset = frozen snapshot; `experiment drift` sweeps a
                  range when unset)
                  --online-learning [true|false]   learn() from each
                  control epoch's realized reward during online evaluation
                  (default true; false = pure re-decision from the trained
                  table, the `experiment drift` ablation)
OPTIONS (drift):  --drift \"T:rate=K,net=weak;...\"   piecewise drift
                  schedule over the horizon (rate multipliers + link-cond
                  overrides; keys rate|net|dev|edge) — the scenario
                  `experiment drift` replays against frozen/online/oracle
                  policies
OPTIONS (admission): --admission admit_all|deadline_shed|defer|degrade
                  ingress admission policy for `experiment drift` /
                  `experiment overload` (rejected elsewhere — other
                  commands would silently run unpoliced):
                  every arrival carries a deadline and may be shed,
                  deferred to the next control tick, or degraded to a
                  cheaper model when its predicted completion misses it
                  ([admission] policy/deadline_ms/slo_multiplier/
                  defer_budget; unset = admit everything, bit-identical
                  to the pre-admission engine)
                  --slo K   deadline = K x the oracle latency (the
                  fastest unloaded d0 response per device; K > 1.0,
                  default 3.0; [admission] deadline_ms pins an absolute
                  SLO instead) — `experiment overload` sweeps arrival
                  rates past saturation comparing the policies on
                  goodput vs tail latency
OPTIONS (fleet):  --fleet-scenarios a,b|all  --fleet-policies a,b|all
                  slice of the `experiment fleet` matrix: named scenarios
                  (diurnal, flash_crowd, brownout, churn, multi_tenant) x
                  placement tiers x admission policies into one
                  comparative report (results/fleet.csv + fleet.json)
                  --fast   smoke slice (2 scenarios x 2 policies, short
                  horizon; EECO_FAST=1 does the same)
OPTIONS (faults): --faults \"T:edge0=down;T2:edge0=up;...\"   piecewise
                  fault-injection schedule over the horizon (targets
                  edgeK|cloud|net, states up|down|flap(period_ms,duty));
                  `experiment drift` replays its drifted trace under the
                  schedule (rejected elsewhere — other commands would
                  silently run fault-free). A failed node drains its
                  queue, arrivals to it error out, and the control plane
                  re-routes around the outage via the live down mask;
                  failures are priced like shed load in the online reward
                  --retry none|backoff|failover   what a failed attempt
                  does next: give up (terminal failure), re-try the same
                  placement after a jittered exponential delay, or
                  re-place onto the cheapest healthy alternative
                  ([retry] budget caps attempts per request, default 3)
                  --retry-timeout MS   per-attempt timeout (0 = off);
                  timed-out attempts are evicted from wherever they
                  queue and recycled through the retry policy
                  ([faults] spec; [retry] policy/budget/timeout_ms/
                  backoff_ms in TOML; empty spec + timeout 0 = identity,
                  bit-identical to the fault-free engine; `experiment
                  chaos` sweeps fault intensity x retry policy into
                  results/chaos.csv + chaos.json with a gating
                  healthy-anchor digest self-check)
OPTIONS (sharding): --shards N   partition the open-loop DES by edge
                  domain: N independent event loops (device + home-edge
                  traffic never crosses shards; the cloud uplink is the
                  only coupling), arrivals streamed per conservative
                  sync window instead of materialized — bitwise
                  identical to the serial engine for any N
                  --shard-window MS   override the sync window (default
                  0 = the memoized service tables' minimum cloud path
                  overhead, the conservative bound)
                  ([sharding] shards/window_ms in TOML; `experiment
                  scale` sweeps shard counts x request volumes into
                  results/scale.csv + scale.json with a gating
                  shard==serial digest self-check — --fast / EECO_FAST=1
                  runs the CI smoke slice)
OPTIONS (perf):   --scheduler heap|wheel   event-queue implementation
                  behind every DES engine (serial core, each shard, the
                  cloud stage and the arrival merge): `heap` (default)
                  is the BinaryHeap reference, `wheel` a hierarchical
                  timing wheel with O(1) amortized scheduling —
                  property-pinned bitwise identical to the heap, so the
                  only difference is queue-op cost ([perf] scheduler in
                  TOML; `experiment scale` reports events/sec plus
                  scheduled/fired/queue-op/peak-depth counters per cell)
                  --wheel-granularity span|auto|MS   timing-wheel bucket
                  width: `span` (default) fits each rebase batch's time
                  span, `auto` self-tunes from an EMA of the observed
                  inter-event gap at rebase points, a positive MS pins a
                  fixed width — heap runs ignore it and every mode is
                  property-pinned bitwise identical to the heap ([perf]
                  wheel_granularity in TOML)
                  --decision-cache on|off|N   memoized control-plane
                  decisions: frozen evaluations cache the agent's (and
                  drift oracle's) choice per quantized observed state +
                  node-health mask + admission policy, replaying hits
                  with zero RNG draws — property-pinned bitwise identical
                  to off; N sets the LRU capacity (on = 512), and
                  `experiment overhead` gates the hit rate and cache
                  transparency ([perf] decision_cache in TOML;
                  cache-hit/miss, retable-row and wheel-rebase counters
                  surface in the drift/chaos/scale reports)
                  --approx-threshold N   bounded-memory latency
                  summaries: runs completing more than N requests
                  answer TrafficMetrics percentiles from a 64-bucket
                  log2 histogram (error <= 2x for >= 1 ms) instead of
                  sorting every response; 0 (default) = always exact
                  ([metrics] approx_threshold in TOML)
OPTIONS (telemetry): --telemetry PATH  attach the flight recorder and
                  write per-request trace spans (arrival, admission
                  verdict, service start, completion) + per-tick gauges
                  (backlog, en-route, utilization) to PATH; off by
                  default and bitwise-transparent to every metric
                  --telemetry-format jsonl|csv   trace encoding
                  --telemetry-gauges tick|event   gauge sampling: per
                  control tick (default) or additionally at every
                  backlog-changing event (full queue trajectories; both
                  bitwise-transparent, sink failures degrade to a
                  dropped_records count instead of panicking)
                  ([telemetry] enabled/capacity/format/path/gauges in
                  TOML; `experiment fleet` writes one trace per matrix
                  cell under results/fleet_telemetry/)",
        ids = experiments::ALL.join(",")
    );
}

fn cmd_experiment(args: &Args, cfg: Config) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("experiment id required (or 'all')"))?
        .clone();
    let ctx = ExpCtx::new(cfg);
    if id == "all" {
        for id in experiments::ALL {
            experiments::run(id, &ctx)?;
        }
    } else {
        experiments::run(&id, &ctx)?;
    }
    Ok(())
}

fn cmd_train(args: &Args, cfg: Config) -> Result<()> {
    let ctx = ExpCtx::new(cfg.clone());
    let steps = args.usize("steps", cfg.steps);
    println!(
        "training {} | users={} scenario={} constraint={} steps={}",
        cfg.algo.label(),
        cfg.users,
        cfg.scenario,
        cfg.constraint.label(),
        steps
    );
    let env = ctx.env(cfg.scenario.clone(), cfg.constraint, cfg.seed);
    let agent = ctx.make_agent(cfg.algo, cfg.users, cfg.seed + 1)?;
    let mut orch = Orchestrator::new(env, agent);
    let t0 = std::time::Instant::now();
    let res = orch.train_full(steps, (steps / 20).max(1));
    println!(
        "trained {} steps in {:.1}s; converged at {}",
        res.steps,
        t0.elapsed().as_secs_f64(),
        res.converged_at.map(|s| s.to_string()).unwrap_or("-".into())
    );
    for (step, r) in &res.curve {
        println!("  step {step:>8}  avg reward {r:10.1}");
    }
    let (d, ms, acc) = orch.representative_decision();
    println!("policy (idle state): {d}  -> avg {ms:.1} ms @ {acc:.1}% top-5");
    if let Some((od, oms)) = bruteforce::optimal(&orch.env, orch.env.threshold) {
        println!("brute-force optimum: {od}  -> avg {oms:.1} ms");
        println!("gap: {:+.1}%", (ms / oms - 1.0) * 100.0);
    }
    // `--save path.qtab` persists the trained Q-table (QL/SOTA only; the
    // DQN path checkpoints through agent::checkpoint::save_dqn).
    if let Some(path) = args.get("save") {
        if cfg.algo != Algo::Dqn {
            // rebuild a concrete agent from the boxed one via export is not
            // possible; retrain compactly instead would waste work, so we
            // train the concrete type directly when saving.
            let mut concrete = eeco::agent::qlearning::QTableAgent::new(
                cfg.users,
                cfg.hyper.clone(),
                eeco::agent::ActionSet::full_for(&ctx.topology(cfg.users)),
                cfg.seed + 1,
            );
            let mut env2 = ctx.env(cfg.scenario.clone(), cfg.constraint, cfg.seed);
            for _ in 0..steps {
                let s = env2.encoded();
                let d = eeco::agent::Agent::decide(&mut concrete, &s, true);
                let out = env2.step(&d);
                let s2 = env2.encoded();
                eeco::agent::Agent::learn(&mut concrete, &s, &d, out.reward, &s2);
            }
            eeco::agent::checkpoint::save_qtable(&concrete, path)?;
            println!("saved Q-table checkpoint -> {path}");
        } else {
            println!("--save for DQN: use agent::checkpoint::save_dqn programmatically");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args, cfg: Config) -> Result<()> {
    let rounds = args.usize("rounds", 10);
    let rt = std::sync::Arc::new(SharedRuntime::load(&cfg.artifacts_dir)?);
    let _ = Mode::Measured; // serving is inherently measured mode
    println!(
        "serving: users={} scenario={} constraint={} rounds={rounds}",
        cfg.users,
        cfg.scenario,
        cfg.constraint.label()
    );

    // Train a quick policy in sim, then serve with it for real.
    let ctx = ExpCtx::new(cfg.clone());
    let mut orch = ctx.trained(
        cfg.scenario.clone(),
        cfg.constraint,
        Algo::QLearning,
        experiments::scaled(30_000),
        cfg.seed,
    )?;
    let (decision, ms_pred, acc) = orch.representative_decision();
    println!("policy: {decision}  (sim-predicted avg {ms_pred:.0} ms @ {acc:.1}%)");

    let models: Vec<ModelId> = decision.0.iter().map(|a| a.model).collect();
    rt.warmup_serving(&models)?;

    let network = eeco::network::Network::with_edges(
        cfg.scenario.clone(),
        cfg.calibration.clone(),
        cfg.topology.edges(),
    );
    let cluster = eeco::cluster::Cluster::for_topology(&network.topo, rt);
    let router = Router::for_topology(decision, &network.topo);
    let mut wl = WorkloadGen::new(Arrival::Periodic { period_ms: 1000.0 }, cfg.users, cfg.seed);
    let serve_cfg = ServeConfig::default();

    let mut all = Vec::new();
    let t0 = std::time::Instant::now();
    if args.flag("trace") {
        // Open-loop serving: play an arrival schedule (the [traffic]
        // process) through the virtual-clock dynamic batcher.
        let process = cfg.traffic.arrival().map_err(|e| anyhow!(e))?;
        let trace = eeco::sim::arrivals::schedule(
            process,
            cfg.users,
            cfg.traffic.horizon_ms,
            cfg.seed,
        );
        println!(
            "trace mode: {} requests over {:.0} ms virtual time",
            trace.len(),
            cfg.traffic.horizon_ms
        );
        all = serve_trace(&cluster, &network, &router, &trace, &serve_cfg, 50.0)?;
    } else {
        for round in 0..rounds {
            let reqs = wl.sync_round(round as f64 * 1000.0);
            let recs = serve_round(&cluster, &network, &router, &reqs, &serve_cfg)?;
            all.extend(recs);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if all.is_empty() {
        println!("no requests served (empty trace?)");
        return Ok(());
    }

    let mut rows = Vec::new();
    let mut total = 0.0;
    for r in all.iter().take(cfg.users) {
        rows.push(vec![
            format!("S{}", r.device + 1),
            r.action.to_string(),
            format!("{:.1}", r.network_ms),
            format!("{:.1}", r.queue_ms),
            format!("{:.1}", r.compute_ms),
            format!("{:.1}", r.total_ms),
            r.batch_size.to_string(),
        ]);
    }
    for r in &all {
        total += r.total_ms;
    }
    print!(
        "{}",
        render_table(
            &["device", "action", "net ms", "queue ms", "compute ms", "total ms", "batch"],
            &rows
        )
    );
    println!(
        "served {} requests in {:.2}s wall; avg modeled+measured response {:.1} ms; throughput {:.1} req/s",
        all.len(),
        wall,
        total / all.len() as f64,
        all.len() as f64 / wall
    );
    Ok(())
}

fn cmd_calibrate(cfg: Config) -> Result<()> {
    let rt = SharedRuntime::load(&cfg.artifacts_dir)?;
    println!("measuring per-model PJRT compute time (batch 1, this host):");
    let (h, w, c) = rt.manifest.img;
    let img = eeco::sim::workload::synth_image(1, h, w, c);
    let mut rows = Vec::new();
    for m in ModelId::all() {
        // warmup + measure
        rt.infer(m, &img, 1)?;
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            rt.infer(m, &img, 1)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        let info = model_info(m);
        rows.push(vec![
            m.to_string(),
            format!("{:.0}", info.mmacs),
            format!("{:?}", info.precision),
            format!("{ms:.2}"),
            format!("{:.3}", ms / info.mmacs),
        ]);
    }
    print!(
        "{}",
        render_table(&["model", "paper MMACs", "precision", "ms (this host)", "ms/MMAC"], &rows)
    );
    println!("note: sim-mode ms/MMAC for the paper's a1.medium is {:.3}", cfg.calibration.ms_per_mmac[0]);
    Ok(())
}

fn cmd_info(cfg: Config) -> Result<()> {
    println!("EECO — model catalog (paper Table 4):");
    let mut rows = Vec::new();
    for m in &CATALOG {
        rows.push(vec![
            m.id.to_string(),
            format!("{}", m.alpha),
            format!("{:?}", m.precision),
            format!("{}", m.mmacs),
            format!("{}", m.top1),
            format!("{}", m.top5),
        ]);
    }
    print!(
        "{}",
        render_table(&["model", "alpha", "precision", "MMACs", "top-1 %", "top-5 %"], &rows)
    );
    println!("\nscenarios (Table 5): EXP-A..D over {} users; current: {}", cfg.users, cfg.scenario);
    match SharedRuntime::load(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!(
                "artifacts: {} graphs, {} DQN variants, image {:?}, {} classes, pallas={}",
                rt.manifest.graphs.len(),
                rt.manifest.dqn.len(),
                rt.manifest.img,
                rt.manifest.classes,
                rt.manifest.use_pallas
            );
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    Ok(())
}
