//! Measured-mode cluster substrate: simulated end/edge/cloud nodes that
//! execute *real* PJRT MobileNet inference on per-node thread pools sized
//! to the paper's vCPU counts (Table 6: end 1, edge 2, cloud 4), so
//! concurrency contention is physically real wall-clock time.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::Calibration;
use crate::runtime::SharedRuntime;
use crate::sim::workload::synth_image;
use crate::types::{ModelId, Tier};
use crate::util::pool::ThreadPool;

/// One compute node.
pub struct Node {
    pub name: String,
    pub tier: Tier,
    pub pool: Arc<ThreadPool>,
    rt: Arc<SharedRuntime>,
}

impl Node {
    pub fn new(name: &str, tier: Tier, vcpus: usize, rt: Arc<SharedRuntime>) -> Node {
        Node {
            name: name.to_string(),
            tier,
            pool: Arc::new(ThreadPool::new(vcpus, name)),
            rt: Arc::clone(&rt),
        }
    }

    /// Execute one inference batch synchronously on this node's pool,
    /// returning (logits, compute wall-time ms).
    pub fn infer_batch(&self, model: ModelId, ids: &[u64]) -> Result<(Vec<f32>, f64)> {
        let rt = Arc::clone(&self.rt);
        let (h, w, c) = rt.manifest.img;
        let mut images = Vec::with_capacity(ids.len() * h * w * c);
        for &id in ids {
            images.extend(synth_image(id, h, w, c));
        }
        let n = ids.len();
        let out = self.pool.run(move || {
            let t0 = Instant::now();
            let logits = rt.infer(model, &images, n);
            (logits, t0.elapsed().as_secs_f64() * 1e3)
        });
        let (logits, ms) = out;
        Ok((logits?, ms))
    }
}

/// The end-edge-cloud topology (paper Table 6 shape).
pub struct Cluster {
    pub devices: Vec<Node>,
    pub edge: Node,
    pub cloud: Node,
}

impl Cluster {
    pub fn new(users: usize, cal: &Calibration, rt: Arc<SharedRuntime>) -> Cluster {
        let devices = (0..users)
            .map(|i| Node::new(&format!("S{}", i + 1), Tier::Local, cal.vcpus[0], Arc::clone(&rt)))
            .collect();
        Cluster {
            devices,
            edge: Node::new("E", Tier::Edge, cal.vcpus[1], Arc::clone(&rt)),
            cloud: Node::new("C", Tier::Cloud, cal.vcpus[2], rt),
        }
    }

    /// Node executing `tier` for requests from `device`.
    pub fn node_for(&self, device: usize, tier: Tier) -> &Node {
        match tier {
            Tier::Local => &self.devices[device],
            Tier::Edge => &self.edge,
            Tier::Cloud => &self.cloud,
        }
    }

    pub fn users(&self) -> usize {
        self.devices.len()
    }
}

// Runtime-dependent tests live in rust/tests/integration_serving.rs; here
// we only verify topology wiring with a stub-free constructor guard.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vcpu_defaults_match_table6() {
        let cal = Calibration::default();
        assert_eq!(cal.vcpus, [1, 2, 4]);
    }
}
