//! Measured-mode cluster substrate: simulated end/edge/cloud nodes that
//! execute *real* PJRT MobileNet inference on per-node thread pools sized
//! to the topology's vCPU counts (paper Table 6: end 1, edge 2, cloud 4),
//! so concurrency contention is physically real wall-clock time. The node
//! set mirrors the sim-side [`Topology`]: one node per device, one per
//! edge, one cloud.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::Calibration;
use crate::runtime::SharedRuntime;
use crate::sim::workload::synth_image;
use crate::types::{ModelId, Placement, Topology};
use crate::util::pool::ThreadPool;

/// One compute node.
pub struct Node {
    pub name: String,
    pub placement: Placement,
    pub pool: Arc<ThreadPool>,
    rt: Arc<SharedRuntime>,
}

impl Node {
    pub fn new(name: &str, placement: Placement, vcpus: usize, rt: Arc<SharedRuntime>) -> Node {
        Node {
            name: name.to_string(),
            placement,
            pool: Arc::new(ThreadPool::new(vcpus, name)),
            rt: Arc::clone(&rt),
        }
    }

    /// Execute one inference batch synchronously on this node's pool,
    /// returning (logits, compute wall-time ms).
    pub fn infer_batch(&self, model: ModelId, ids: &[u64]) -> Result<(Vec<f32>, f64)> {
        let rt = Arc::clone(&self.rt);
        let (h, w, c) = rt.manifest.img;
        let mut images = Vec::with_capacity(ids.len() * h * w * c);
        for &id in ids {
            images.extend(synth_image(id, h, w, c));
        }
        let n = ids.len();
        let out = self.pool.run(move || {
            let t0 = Instant::now();
            let logits = rt.infer(model, &images, n);
            (logits, t0.elapsed().as_secs_f64() * 1e3)
        });
        let (logits, ms) = out;
        Ok((logits?, ms))
    }
}

/// The end-edge-cloud node set (paper Table 6 shape, N edges).
pub struct Cluster {
    pub devices: Vec<Node>,
    pub edges: Vec<Node>,
    pub cloud: Node,
}

impl Cluster {
    /// The paper's single-edge cluster.
    pub fn new(users: usize, cal: &Calibration, rt: Arc<SharedRuntime>) -> Cluster {
        let devices = (0..users)
            .map(|i| {
                Node::new(&format!("S{}", i + 1), Placement::Local, cal.vcpus[0], Arc::clone(&rt))
            })
            .collect();
        Cluster {
            devices,
            edges: vec![Node::new("E", Placement::Edge(0), cal.vcpus[1], Arc::clone(&rt))],
            cloud: Node::new("C", Placement::Cloud, cal.vcpus[2], rt),
        }
    }

    /// Cluster mirroring an explicit topology: one pool per device, one
    /// per edge node (named E, E2, E3, ...), one cloud.
    pub fn for_topology(topo: &Topology, rt: Arc<SharedRuntime>) -> Cluster {
        let devices = (0..topo.users())
            .map(|i| {
                Node::new(
                    &format!("S{}", i + 1),
                    Placement::Local,
                    topo.devices[i].vcpus,
                    Arc::clone(&rt),
                )
            })
            .collect();
        let edges = topo
            .edges
            .iter()
            .enumerate()
            .map(|(j, e)| {
                let name = Placement::Edge(j).to_string();
                Node::new(&name, Placement::Edge(j), e.vcpus, Arc::clone(&rt))
            })
            .collect();
        Cluster { devices, edges, cloud: Node::new("C", Placement::Cloud, topo.cloud.vcpus, rt) }
    }

    /// Node executing `p` for requests from `device`.
    pub fn node_for(&self, device: usize, p: Placement) -> &Node {
        match p {
            Placement::Local => &self.devices[device],
            Placement::Edge(j) => &self.edges[j],
            Placement::Cloud => &self.cloud,
        }
    }

    pub fn users(&self) -> usize {
        self.devices.len()
    }
}

// Runtime-dependent tests live in rust/tests/integration_serving.rs; here
// we only verify topology wiring with a stub-free constructor guard.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vcpu_defaults_match_table6() {
        let cal = Calibration::default();
        assert_eq!(cal.vcpus, [1, 2, 4]);
    }
}
