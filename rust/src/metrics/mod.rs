//! Run metrics: per-request records, aggregate response-time/accuracy
//! summaries, training curves, and CSV/JSON export for the experiment
//! drivers (results/ is what EXPERIMENTS.md tables are generated from).

use std::fmt::Write as _;

use crate::types::Decision;
use crate::util::json::Json;
use crate::util::stats::{OnlineStats, Sample};

/// One synchronous round's outcome.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub step: usize,
    pub decision: Decision,
    pub response_ms: Vec<f64>,
    pub avg_response_ms: f64,
    pub avg_accuracy: f64,
    pub reward: f64,
    pub epsilon: f64,
}

/// Aggregated metrics over a run.
#[derive(Debug, Default)]
pub struct RunMetrics {
    pub response: Sample,
    pub accuracy: OnlineStats,
    pub reward: OnlineStats,
    pub rounds: usize,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: &RoundRecord) {
        self.response.push(rec.avg_response_ms);
        self.accuracy.push(rec.avg_accuracy);
        self.reward.push(rec.reward);
        self.rounds += 1;
    }

    pub fn summary(&mut self) -> Json {
        Json::obj()
            .set("rounds", self.rounds)
            .set("avg_response_ms", self.response.mean())
            .set("p50_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(50.0) })
            .set("p95_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(95.0) })
            .set("p99_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(99.0) })
            .set("avg_accuracy", self.accuracy.mean())
            .set("avg_reward", self.reward.mean())
    }
}

/// Per-request latency distribution summary (open-loop / trace serving).
/// `PartialEq` is bitwise-style float equality — what the parallel-sweep
/// property tests use to assert parallel rows equal serial rows exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize a latency sample (NaNs never appear in DES output).
    pub fn of(values: &[f64]) -> LatencySummary {
        if values.is_empty() {
            return LatencySummary {
                count: 0,
                mean_ms: f64::NAN,
                p50_ms: f64::NAN,
                p95_ms: f64::NAN,
                p99_ms: f64::NAN,
                max_ms: f64::NAN,
            };
        }
        let mut s = Sample::new();
        for &v in values {
            s.push(v);
        }
        LatencySummary {
            count: values.len(),
            mean_ms: s.mean(),
            p50_ms: s.pct(50.0),
            p95_ms: s.pct(95.0),
            p99_ms: s.pct(99.0),
            max_ms: s.pct(100.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("max_ms", self.max_ms)
    }
}

/// Log2 histogram bucket for a latency in ms: bucket `b` holds values in
/// `[2^(b-1), 2^b)`, sub-millisecond values land in 0 (the same buckets
/// the sharded engine's `StreamSummary` uses).
fn log2_bucket(ms: f64) -> usize {
    (64 - (ms.max(0.0) as u64).leading_zeros() as usize).min(63)
}

/// Streaming latency summarizer with O(1) memory: exact count/sum/max,
/// percentiles answered from a 64-bucket log2 histogram. The
/// bounded-memory half of [`TrafficMetrics::from_outcome_with`] — a
/// reported percentile is the upper bound of its bucket, i.e. at most 2x
/// the true value for latencies >= 1 ms (sub-millisecond values report
/// as 0), while count, mean and max stay exact.
#[derive(Debug, Clone)]
struct ApproxLatency {
    count: usize,
    sum_ms: f64,
    max_ms: f64,
    hist: [u64; 64],
}

impl ApproxLatency {
    fn new() -> ApproxLatency {
        ApproxLatency { count: 0, sum_ms: 0.0, max_ms: f64::NEG_INFINITY, hist: [0; 64] }
    }

    fn push(&mut self, ms: f64) {
        self.count += 1;
        self.sum_ms += ms;
        if ms > self.max_ms {
            self.max_ms = ms;
        }
        self.hist[log2_bucket(ms)] += 1;
    }

    /// Upper bound of the histogram bucket containing quantile `q` (0..1).
    fn pct(&self, q: f64) -> f64 {
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, n) in self.hist.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return if b == 0 { 0.0 } else { (1u64 << b) as f64 };
            }
        }
        self.max_ms
    }

    fn summary(&self) -> LatencySummary {
        if self.count == 0 {
            return LatencySummary::of(&[]);
        }
        LatencySummary {
            count: self.count,
            mean_ms: self.sum_ms / self.count as f64,
            p50_ms: self.pct(0.50),
            p95_ms: self.pct(0.95),
            p99_ms: self.pct(0.99),
            max_ms: self.max_ms,
        }
    }
}

/// Metrics of one open-loop (asynchronous-arrival) evaluation: response
/// percentiles, queueing decomposition, throughput and queue-depth
/// observability, plus the policy that served the trace. Produced by
/// `Orchestrator::evaluate_async`/`evaluate_online` and the
/// `traffic_sweep`/`drift` experiments.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMetrics {
    /// The routing policy (for the online control loop: the last epoch's).
    pub decision: Decision,
    pub response: LatencySummary,
    /// Waiting time only (shared-link + compute-queue), per request.
    pub queueing: LatencySummary,
    pub throughput_rps: f64,
    /// Virtual time of the last departure.
    pub makespan_ms: f64,
    pub requests: usize,
    /// Largest instantaneous backlog any compute node held
    /// ([`crate::sim::des::DesOutcome::peak_backlog`]).
    pub peak_backlog: usize,
    /// Time-weighted mean backlog of the busiest compute node
    /// ([`crate::sim::des::DesOutcome::busiest_mean_backlog`]).
    pub busiest_mean_backlog: f64,
    /// Arrivals the admission policy rejected at ingress (0 without an
    /// `[admission]` config).
    pub shed: usize,
    /// Defer events (bounded re-queues to a later control tick).
    pub deferrals: usize,
    /// Requests admitted with a degraded (cheaper) model variant.
    pub degraded: usize,
    /// Completions that blew their stamped deadline (0 when none).
    pub deadline_misses: usize,
    /// On-time completions per second of virtual time
    /// ([`crate::sim::des::DesOutcome::goodput_rps`]): normalized by the
    /// arrival horizon when the run carries one — immune to the makespan
    /// shrink a shedding policy causes — else by the makespan, where it
    /// equals `throughput_rps` when no deadlines are stamped.
    pub goodput_rps: f64,
    /// Latency split per deadline outcome: summaries over on-time and
    /// late completions (None when that class is empty — note
    /// `requests = on-time + late` always holds).
    pub response_on_time: Option<LatencySummary>,
    pub response_late: Option<LatencySummary>,
    /// Admitted requests that failed terminally under a fault plan
    /// (timeout/outage with retry budget exhausted); 0 without faults.
    pub failed: usize,
    /// Per-attempt timeouts observed (an eviction, not necessarily
    /// terminal — a retried attempt counts here and in `retries`).
    pub timed_out: usize,
    /// Re-admissions performed by the retry policy (failovers included).
    pub retries: usize,
    /// Retries that switched to a different healthy placement.
    pub failovers: usize,
    /// completed / (completed + failed); 1.0 when nothing resolved.
    pub availability: f64,
}

impl TrafficMetrics {
    /// Exact-percentile summary (the historical path — materializes one
    /// `Vec<f64>` per latency class). Equivalent to
    /// [`TrafficMetrics::from_outcome_with`] with threshold 0.
    pub fn from_outcome(
        decision: &Decision,
        outcome: &crate::sim::des::DesOutcome,
    ) -> TrafficMetrics {
        TrafficMetrics::from_outcome_with(decision, outcome, 0)
    }

    /// [`TrafficMetrics::from_outcome`] with a bounded-memory switch:
    /// when `approx_threshold > 0` and more than that many requests
    /// completed, percentiles stream through a 64-bucket log2 histogram
    /// ([`ApproxLatency`]) instead of collecting every latency into a
    /// `Vec<f64>`. On the approximate path a percentile is its bucket's
    /// upper bound — at most 2x the true value for latencies >= 1 ms —
    /// while count, mean and max stay exact. With threshold 0 (the
    /// default everywhere) or a completion count at/below the threshold,
    /// the exact path runs unchanged and bit-identical to the historical
    /// `from_outcome` (the test suite pins this).
    pub fn from_outcome_with(
        decision: &Decision,
        outcome: &crate::sim::des::DesOutcome,
        approx_threshold: usize,
    ) -> TrafficMetrics {
        let approx = approx_threshold > 0 && outcome.completed.len() > approx_threshold;
        let (response, queueing, response_on_time, response_late, misses) = if approx {
            let mut resp = ApproxLatency::new();
            let mut queue = ApproxLatency::new();
            let mut on_time = ApproxLatency::new();
            let mut late = ApproxLatency::new();
            for c in &outcome.completed {
                resp.push(c.response_ms);
                queue.push(c.link_wait_ms + c.queue_ms);
                if c.on_time() {
                    on_time.push(c.response_ms);
                } else {
                    late.push(c.response_ms);
                }
            }
            let opt = |a: &ApproxLatency| (a.count > 0).then(|| a.summary());
            (resp.summary(), queue.summary(), opt(&on_time), opt(&late), late.count)
        } else {
            let waits: Vec<f64> =
                outcome.completed.iter().map(|c| c.link_wait_ms + c.queue_ms).collect();
            let mut on_time = Vec::new();
            let mut late = Vec::new();
            for c in &outcome.completed {
                if c.on_time() {
                    on_time.push(c.response_ms);
                } else {
                    late.push(c.response_ms);
                }
            }
            let summarize =
                |v: &Vec<f64>| if v.is_empty() { None } else { Some(LatencySummary::of(v)) };
            (
                LatencySummary::of(&outcome.responses_ms()),
                LatencySummary::of(&waits),
                summarize(&on_time),
                summarize(&late),
                late.len(),
            )
        };
        TrafficMetrics {
            decision: decision.clone(),
            response,
            queueing,
            throughput_rps: outcome.throughput_rps(),
            makespan_ms: outcome.makespan_ms,
            requests: outcome.completed.len(),
            peak_backlog: outcome.peak_backlog(),
            busiest_mean_backlog: outcome.busiest_mean_backlog(),
            shed: outcome.shed,
            deferrals: outcome.deferrals,
            degraded: outcome.degraded,
            deadline_misses: misses,
            goodput_rps: outcome.goodput_rps(),
            response_on_time,
            response_late,
            failed: outcome.failed,
            timed_out: outcome.timed_out,
            retries: outcome.retries,
            failovers: outcome.failovers,
            availability: outcome.availability(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("decision", self.decision.to_string())
            .set("requests", self.requests)
            .set("throughput_rps", self.throughput_rps)
            .set("goodput_rps", self.goodput_rps)
            .set("makespan_ms", self.makespan_ms)
            .set("peak_backlog", self.peak_backlog)
            .set("busiest_mean_backlog", self.busiest_mean_backlog)
            .set("shed", self.shed)
            .set("deferrals", self.deferrals)
            .set("degraded", self.degraded)
            .set("deadline_misses", self.deadline_misses)
            .set("failed", self.failed)
            .set("timed_out", self.timed_out)
            .set("retries", self.retries)
            .set("failovers", self.failovers)
            .set("availability", self.availability)
            .set("response", self.response.to_json())
            .set("queueing", self.queueing.to_json());
        if let Some(s) = &self.response_on_time {
            j = j.set("response_on_time", s.to_json());
        }
        if let Some(s) = &self.response_late {
            j = j.set("response_late", s.to_json());
        }
        j
    }
}

/// One control epoch of the online loop: the decision in force over
/// `[start_ms, end_ms)`, what it observed-and-earned, and the agent's
/// exploration rate when it decided. The per-epoch timeline is the
/// adaptation story a frozen-snapshot evaluation cannot tell.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub start_ms: f64,
    pub end_ms: f64,
    /// The decision routing arrivals of this epoch.
    pub decision: Decision,
    /// Exploration rate in force at the decision (0 for greedy).
    pub epsilon: f64,
    /// Requests *completed* during the epoch (what the realized reward is
    /// computed over; arrivals routed here may complete later).
    pub requests: usize,
    /// Latency summary of this epoch's completions.
    pub response: LatencySummary,
    /// Eq. 4 reward realized over the epoch's completions (0 when none
    /// completed — such epochs are skipped by online learning).
    ///
    /// Deliberately SARSA-like: the reward is the system's realized cost
    /// *while this decision was in force*, so right after a policy
    /// switch it still includes the drain of requests launched under the
    /// previous decision (a good switch can be penalized for one
    /// backlog-drain epoch before its own performance shows). The
    /// alternative — crediting each decision only with completions it
    /// launched — would starve the learner of any signal exactly when a
    /// saturated placement never finishes its own arrivals in-epoch,
    /// which is the regime online adaptation exists for.
    ///
    /// Under an admission policy each shed arrival additionally counts as
    /// one worst-case (`penalty_ms`) response in the epoch mean, so
    /// `learn()` sees the cost of rejecting work, not just the rosy
    /// latency of the survivors.
    pub reward: f64,
    /// Arrivals shed at ingress during the epoch.
    pub shed: usize,
    /// Defer events during the epoch.
    pub deferrals: usize,
    /// Degraded admissions during the epoch.
    pub degraded: usize,
    /// Epoch completions that blew their deadline.
    pub deadline_misses: usize,
    /// Terminal failures during the epoch (priced like shed arrivals in
    /// the reward — the learner must feel an outage, not just observe a
    /// thinner completion stream).
    pub failed: usize,
}

/// Outcome of one online (control-plane) evaluation:
/// the per-epoch decision timeline, aggregate per-request metrics, and
/// the raw DES outcome for custom splits.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    pub epochs: Vec<EpochRecord>,
    pub metrics: TrafficMetrics,
    pub outcome: crate::sim::des::DesOutcome,
    /// Online `learn()` calls performed during the run.
    pub learn_steps: usize,
}

impl OnlineReport {
    /// Latency summaries of requests arriving before vs from `t_ms` —
    /// the pre-drift / post-drift split of a drift scenario.
    pub fn split_at(&self, t_ms: f64) -> (LatencySummary, LatencySummary) {
        let mut pre = Vec::new();
        let mut post = Vec::new();
        for c in &self.outcome.completed {
            if c.arrival_ms < t_ms {
                pre.push(c.response_ms);
            } else {
                post.push(c.response_ms);
            }
        }
        (LatencySummary::of(&pre), LatencySummary::of(&post))
    }

    /// How long after a drift at `onset_ms` the control plane changed its
    /// decision: the start of the first epoch at or after the onset whose
    /// decision differs from the one in force when the drift hit, minus
    /// the onset. None when the policy never moved (or nothing preceded
    /// the onset).
    pub fn adaptation_lag_ms(&self, onset_ms: f64) -> Option<f64> {
        let before = self.epochs.iter().rev().find(|e| e.start_ms < onset_ms)?;
        let frozen = before.decision.clone();
        self.epochs
            .iter()
            .find(|e| e.start_ms >= onset_ms && e.decision != frozen)
            .map(|e| e.start_ms - onset_ms)
    }

    /// Number of epoch boundaries where the decision actually changed.
    pub fn decision_changes(&self) -> usize {
        self.epochs.windows(2).filter(|w| w[0].decision != w[1].decision).count()
    }
}

/// Minimal CSV writer: header + rows of f64/string cells.
#[derive(Debug, Default)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv row width");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn save(&self, dir: &str, name: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{name}.csv");
        std::fs::write(&path, self.to_string())?;
        Ok(path)
    }
}

/// Render a fixed-width text table (the experiment drivers' stdout view).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let padded: Vec<String> =
            cells.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}", w = w)).collect();
        let _ = writeln!(out, "| {} |", padded.join(" | "));
    };
    line(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let _ = writeln!(out, "|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
    for r in rows {
        line(&mut out, r);
    }
    out
}

pub fn save_json(dir: &str, name: &str, j: &Json) -> std::io::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}.json");
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Action, ModelId, Tier};

    fn rec(ms: f64) -> RoundRecord {
        RoundRecord {
            step: 0,
            decision: Decision(vec![Action { placement: Tier::Local, model: ModelId(0) }]),
            response_ms: vec![ms],
            avg_response_ms: ms,
            avg_accuracy: 89.9,
            reward: -ms,
            epsilon: 0.1,
        }
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = RunMetrics::new();
        for v in [100.0, 200.0, 300.0] {
            m.push(&rec(v));
        }
        let s = m.summary();
        assert_eq!(s.field("rounds").unwrap().as_usize(), Some(3));
        assert_eq!(s.field("avg_response_ms").unwrap().as_f64(), Some(200.0));
    }

    #[test]
    fn csv_escaping_and_shape() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "x,y".into()]);
        let s = c.to_string();
        assert!(s.contains("\"x,y\""));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn csv_rejects_ragged_rows() {
        let mut c = Csv::new(&["a"]);
        c.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn table_render_aligns() {
        let t = render_table(&["col", "x"], &[vec!["value".into(), "1".into()]]);
        assert!(t.contains("| col   | x |"));
        assert!(t.contains("| value | 1 |"));
    }

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!((s.p50_ms - 50.5).abs() < 1e-9);
        assert!(s.p95_ms > 94.0 && s.p95_ms < 96.5);
        assert!(s.p99_ms > 98.0 && s.p99_ms <= 100.0);
        assert_eq!(s.max_ms, 100.0);
        assert_eq!(LatencySummary::of(&[]).count, 0);
    }

    #[test]
    fn online_report_split_and_adaptation_lag() {
        use crate::sim::des::{CompletedRequest, DesOutcome};
        let act = |m: u8| Action { placement: Tier::Local, model: ModelId(m) };
        let dec = |m: u8| Decision(vec![act(m)]);
        let completed: Vec<CompletedRequest> = (0..10)
            .map(|i| {
                let arrival = i as f64 * 1000.0;
                let resp = if i < 5 { 100.0 } else { 400.0 };
                CompletedRequest {
                    id: i as u64,
                    device: 0,
                    action: act(0),
                    arrival_ms: arrival,
                    path_ms: 1.0,
                    link_wait_ms: 0.0,
                    queue_ms: 0.0,
                    service_ms: resp,
                    depart_ms: arrival + resp,
                    response_ms: resp,
                    deadline_ms: f64::INFINITY,
                }
            })
            .collect();
        let outcome = DesOutcome { completed, makespan_ms: 9400.0, ..Default::default() };
        let epoch = |k: usize, m: u8| EpochRecord {
            epoch: k,
            start_ms: k as f64 * 2500.0,
            end_ms: (k + 1) as f64 * 2500.0,
            decision: dec(m),
            epsilon: 0.0,
            requests: 2,
            response: LatencySummary::of(&[100.0]),
            reward: -100.0,
            shed: 0,
            deferrals: 0,
            degraded: 0,
            deadline_misses: 0,
            failed: 0,
        };
        let metrics = TrafficMetrics::from_outcome(&dec(0), &outcome);
        let report = OnlineReport {
            // decision changes one epoch after the drift at 5000
            epochs: vec![epoch(0, 0), epoch(1, 0), epoch(2, 0), epoch(3, 7)],
            metrics,
            outcome,
            learn_steps: 3,
        };
        let (pre, post) = report.split_at(5000.0);
        assert_eq!(pre.count, 5);
        assert_eq!(post.count, 5);
        assert!((pre.mean_ms - 100.0).abs() < 1e-9);
        assert!((post.mean_ms - 400.0).abs() < 1e-9);
        // drift at 5000: epoch 2 (start 5000) kept the old decision,
        // epoch 3 (start 7500) changed -> lag 2500
        assert_eq!(report.adaptation_lag_ms(5000.0), Some(2500.0));
        assert_eq!(report.decision_changes(), 1);
        // onset before any epoch: nothing preceded it
        assert_eq!(report.adaptation_lag_ms(-1.0), None);
    }

    #[test]
    fn traffic_metrics_split_deadline_outcomes_and_goodput() {
        use crate::sim::des::{CompletedRequest, DesOutcome};
        let act = Action { placement: Tier::Local, model: ModelId(0) };
        let req = |id: u64, resp: f64, deadline: f64| CompletedRequest {
            id,
            device: 0,
            action: act,
            arrival_ms: 0.0,
            path_ms: 1.0,
            link_wait_ms: 0.0,
            queue_ms: 0.0,
            service_ms: resp,
            depart_ms: resp,
            response_ms: resp,
            deadline_ms: deadline,
        };
        let outcome = DesOutcome {
            completed: vec![req(0, 100.0, 500.0), req(1, 200.0, 500.0), req(2, 900.0, 500.0)],
            makespan_ms: 1000.0,
            shed: 2,
            deferrals: 1,
            degraded: 1,
            ..Default::default()
        };
        let m = TrafficMetrics::from_outcome(&Decision(vec![act]), &outcome);
        assert_eq!(m.requests, 3);
        assert_eq!((m.shed, m.deferrals, m.degraded), (2, 1, 1));
        assert_eq!(m.deadline_misses, 1);
        assert!((m.throughput_rps - 3.0).abs() < 1e-9);
        assert!((m.goodput_rps - 2.0).abs() < 1e-9);
        let on = m.response_on_time.unwrap();
        assert_eq!(on.count, 2);
        assert!((on.mean_ms - 150.0).abs() < 1e-9);
        let late = m.response_late.unwrap();
        assert_eq!(late.count, 1);
        assert!((late.mean_ms - 900.0).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.field("shed").unwrap().as_usize(), Some(2));
        assert_eq!(j.field("deadline_misses").unwrap().as_usize(), Some(1));

        // no deadlines: goodput == throughput, late split absent
        let plain = DesOutcome {
            completed: vec![req(0, 100.0, f64::INFINITY)],
            makespan_ms: 1000.0,
            ..Default::default()
        };
        let m = TrafficMetrics::from_outcome(&Decision(vec![act]), &plain);
        assert_eq!(m.goodput_rps.to_bits(), m.throughput_rps.to_bits());
        assert!(m.response_late.is_none());
        assert_eq!(m.response_on_time.unwrap().count, 1);
    }

    #[test]
    fn approx_threshold_keeps_small_runs_exact_and_bounds_large_run_error() {
        use crate::sim::des::{CompletedRequest, DesOutcome};
        let act = Action { placement: Tier::Local, model: ModelId(0) };
        let req = |id: u64, resp: f64, deadline: f64| CompletedRequest {
            id,
            device: 0,
            action: act,
            arrival_ms: 0.0,
            path_ms: 1.0,
            link_wait_ms: 0.5,
            queue_ms: resp / 10.0,
            service_ms: resp,
            depart_ms: resp,
            response_ms: resp,
            deadline_ms: deadline,
        };
        let outcome = DesOutcome {
            completed: (1..=100).map(|i| req(i, i as f64, 50.0)).collect(),
            makespan_ms: 1000.0,
            ..Default::default()
        };
        let dec = Decision(vec![act]);
        let exact = TrafficMetrics::from_outcome(&dec, &outcome);
        // threshold 0 and threshold >= count both stay on the exact path,
        // bit-identical to the historical from_outcome
        assert_eq!(TrafficMetrics::from_outcome_with(&dec, &outcome, 0), exact);
        assert_eq!(TrafficMetrics::from_outcome_with(&dec, &outcome, 100), exact);

        // 100 completions over a threshold of 10: the approximate path
        let approx = TrafficMetrics::from_outcome_with(&dec, &outcome, 10);
        assert_eq!(approx.requests, exact.requests);
        assert_eq!(approx.deadline_misses, exact.deadline_misses);
        assert_eq!(approx.response.count, exact.response.count);
        // count/mean/max are exact on the histogram path too
        assert!((approx.response.mean_ms - exact.response.mean_ms).abs() < 1e-9);
        assert_eq!(approx.response.max_ms.to_bits(), exact.response.max_ms.to_bits());
        // percentiles are bucket upper bounds: within 2x of the truth
        // for latencies >= 1 ms (the documented error bound)
        for (a, e) in [
            (approx.response.p50_ms, exact.response.p50_ms),
            (approx.response.p95_ms, exact.response.p95_ms),
            (approx.response.p99_ms, exact.response.p99_ms),
        ] {
            assert!(a >= e / 2.0 && a <= e * 2.0 + 1.0, "approx {a} vs exact {e}");
        }
        let on = approx.response_on_time.unwrap();
        let late = approx.response_late.unwrap();
        assert_eq!(on.count + late.count, 100);
        assert_eq!(late.count, 50);
    }

    #[test]
    fn fully_shed_run_emits_json_that_reparses() {
        use crate::sim::des::DesOutcome;
        use crate::util::json::Json;
        // 100% shed: zero completions, so every LatencySummary field is
        // NaN. The report JSON must still be valid — the crate's own
        // parser has to accept what the writer emits (regression: NaN
        // used to be written verbatim, which Json::parse rejects).
        let act = Action { placement: Tier::Local, model: ModelId(0) };
        let outcome = DesOutcome { shed: 42, horizon_ms: 10_000.0, ..Default::default() };
        let m = TrafficMetrics::from_outcome(&Decision(vec![act]), &outcome);
        assert_eq!(m.requests, 0);
        assert_eq!(m.shed, 42);
        assert!(m.response.mean_ms.is_nan());
        let s = m.to_json().to_string_pretty();
        let back = Json::parse(&s).expect("fully-shed report must reparse");
        assert_eq!(back.field("shed").unwrap().as_usize(), Some(42));
        // NaN percentiles round-trip as null (no value), not garbage
        assert_eq!(back.field("response").unwrap().field("mean_ms").unwrap().as_f64(), None);
        // and a pretty summary with NaN percentiles reparses too
        let mut rm = RunMetrics::new();
        let js = rm.summary().to_string_pretty();
        Json::parse(&js).expect("empty-run summary must reparse");
    }

    #[test]
    fn summary_reports_p95() {
        let mut m = RunMetrics::new();
        for v in 1..=20 {
            m.push(&rec(v as f64 * 10.0));
        }
        let s = m.summary();
        assert!(s.field("p95_response_ms").unwrap().as_f64().unwrap() > 180.0);
    }
}
