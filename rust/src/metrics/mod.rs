//! Run metrics: per-request records, aggregate response-time/accuracy
//! summaries, training curves, and CSV/JSON export for the experiment
//! drivers (results/ is what EXPERIMENTS.md tables are generated from).

use std::fmt::Write as _;

use crate::types::Decision;
use crate::util::json::Json;
use crate::util::stats::{OnlineStats, Sample};

/// One synchronous round's outcome.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub step: usize,
    pub decision: Decision,
    pub response_ms: Vec<f64>,
    pub avg_response_ms: f64,
    pub avg_accuracy: f64,
    pub reward: f64,
    pub epsilon: f64,
}

/// Aggregated metrics over a run.
#[derive(Debug, Default)]
pub struct RunMetrics {
    pub response: Sample,
    pub accuracy: OnlineStats,
    pub reward: OnlineStats,
    pub rounds: usize,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: &RoundRecord) {
        self.response.push(rec.avg_response_ms);
        self.accuracy.push(rec.avg_accuracy);
        self.reward.push(rec.reward);
        self.rounds += 1;
    }

    pub fn summary(&mut self) -> Json {
        Json::obj()
            .set("rounds", self.rounds)
            .set("avg_response_ms", self.response.mean())
            .set("p50_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(50.0) })
            .set("p95_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(95.0) })
            .set("p99_response_ms", if self.response.is_empty() { f64::NAN } else { self.response.pct(99.0) })
            .set("avg_accuracy", self.accuracy.mean())
            .set("avg_reward", self.reward.mean())
    }
}

/// Per-request latency distribution summary (open-loop / trace serving).
/// `PartialEq` is bitwise-style float equality — what the parallel-sweep
/// property tests use to assert parallel rows equal serial rows exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize a latency sample (NaNs never appear in DES output).
    pub fn of(values: &[f64]) -> LatencySummary {
        if values.is_empty() {
            return LatencySummary {
                count: 0,
                mean_ms: f64::NAN,
                p50_ms: f64::NAN,
                p95_ms: f64::NAN,
                p99_ms: f64::NAN,
                max_ms: f64::NAN,
            };
        }
        let mut s = Sample::new();
        for &v in values {
            s.push(v);
        }
        LatencySummary {
            count: values.len(),
            mean_ms: s.mean(),
            p50_ms: s.pct(50.0),
            p95_ms: s.pct(95.0),
            p99_ms: s.pct(99.0),
            max_ms: s.pct(100.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("max_ms", self.max_ms)
    }
}

/// Metrics of one open-loop (asynchronous-arrival) evaluation: response
/// percentiles, queueing decomposition and throughput, plus the policy
/// that served the trace. Produced by `Orchestrator::evaluate_async` and
/// the `traffic_sweep` experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMetrics {
    pub decision: Decision,
    pub response: LatencySummary,
    /// Waiting time only (shared-link + compute-queue), per request.
    pub queueing: LatencySummary,
    pub throughput_rps: f64,
    /// Virtual time of the last departure.
    pub makespan_ms: f64,
    pub requests: usize,
}

impl TrafficMetrics {
    pub fn from_outcome(
        decision: &Decision,
        outcome: &crate::sim::des::DesOutcome,
    ) -> TrafficMetrics {
        let waits: Vec<f64> =
            outcome.completed.iter().map(|c| c.link_wait_ms + c.queue_ms).collect();
        TrafficMetrics {
            decision: decision.clone(),
            response: LatencySummary::of(&outcome.responses_ms()),
            queueing: LatencySummary::of(&waits),
            throughput_rps: outcome.throughput_rps(),
            makespan_ms: outcome.makespan_ms,
            requests: outcome.completed.len(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("decision", self.decision.to_string())
            .set("requests", self.requests)
            .set("throughput_rps", self.throughput_rps)
            .set("makespan_ms", self.makespan_ms)
            .set("response", self.response.to_json())
            .set("queueing", self.queueing.to_json())
    }
}

/// Minimal CSV writer: header + rows of f64/string cells.
#[derive(Debug, Default)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv row width");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn save(&self, dir: &str, name: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{name}.csv");
        std::fs::write(&path, self.to_string())?;
        Ok(path)
    }
}

/// Render a fixed-width text table (the experiment drivers' stdout view).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let padded: Vec<String> =
            cells.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}", w = w)).collect();
        let _ = writeln!(out, "| {} |", padded.join(" | "));
    };
    line(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let _ = writeln!(out, "|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
    for r in rows {
        line(&mut out, r);
    }
    out
}

pub fn save_json(dir: &str, name: &str, j: &Json) -> std::io::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}.json");
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Action, ModelId, Tier};

    fn rec(ms: f64) -> RoundRecord {
        RoundRecord {
            step: 0,
            decision: Decision(vec![Action { placement: Tier::Local, model: ModelId(0) }]),
            response_ms: vec![ms],
            avg_response_ms: ms,
            avg_accuracy: 89.9,
            reward: -ms,
            epsilon: 0.1,
        }
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = RunMetrics::new();
        for v in [100.0, 200.0, 300.0] {
            m.push(&rec(v));
        }
        let s = m.summary();
        assert_eq!(s.field("rounds").unwrap().as_usize(), Some(3));
        assert_eq!(s.field("avg_response_ms").unwrap().as_f64(), Some(200.0));
    }

    #[test]
    fn csv_escaping_and_shape() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "x,y".into()]);
        let s = c.to_string();
        assert!(s.contains("\"x,y\""));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn csv_rejects_ragged_rows() {
        let mut c = Csv::new(&["a"]);
        c.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn table_render_aligns() {
        let t = render_table(&["col", "x"], &[vec!["value".into(), "1".into()]]);
        assert!(t.contains("| col   | x |"));
        assert!(t.contains("| value | 1 |"));
    }

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!((s.p50_ms - 50.5).abs() < 1e-9);
        assert!(s.p95_ms > 94.0 && s.p95_ms < 96.5);
        assert!(s.p99_ms > 98.0 && s.p99_ms <= 100.0);
        assert_eq!(s.max_ms, 100.0);
        assert_eq!(LatencySummary::of(&[]).count, 0);
    }

    #[test]
    fn summary_reports_p95() {
        let mut m = RunMetrics::new();
        for v in 1..=20 {
            m.push(&rec(v as f64 * 10.0));
        }
        let s = m.summary();
        assert!(s.field("p95_response_ms").unwrap().as_f64().unwrap() > 180.0);
    }
}
