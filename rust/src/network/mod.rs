//! Network substrate: per-link conditions (Table 5 scenarios), message
//! costs (Table 12), path overheads per placement, and shared-link
//! queueing for simultaneous uploads.
//!
//! # Topology
//!
//! The network is an explicit [`Topology`] node table: each end device S_i
//! has one uplink to its edge layer; each edge node E_k has one uplink to
//! the cloud and one ingress link that serializes the uploads traversing
//! it. Devices are statically homed (`Topology::home_edge`), so cloud
//! traffic from S_i always rides edge `i % k`'s uplink. The paper's
//! network (Fig 4) is the single-edge instance, which [`Network::new`]
//! builds by default and which reproduces every Table 12 figure exactly.
//!
//! Every request is orchestrated by the cloud-hosted Intelligent
//! Orchestrator, so even locally-executed inferences pay the (small)
//! update + decision control messages — but only offloaded ones pay the
//! image-upload request cost, keeping device performance
//! network-independent as the paper observes in §3.1.

use crate::config::{Calibration, Scenario};
use crate::types::{DeviceId, NetCond, Placement, Topology};

/// The three framework messages of Table 12.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Input image upload to the executing layer (dominant cost).
    Request,
    /// Resource-monitoring broadcast to the orchestrator.
    Update,
    /// Orchestration decision delivery.
    Decision,
}

impl MsgKind {
    pub fn cost_ms(self, cal: &Calibration, cond: NetCond) -> f64 {
        let i = (cond == NetCond::Weak) as usize;
        match self {
            MsgKind::Request => cal.request_ms[i],
            MsgKind::Update => cal.update_ms[i],
            MsgKind::Decision => cal.decision_ms[i],
        }
    }
}

/// Static network model for one scenario over an explicit topology.
#[derive(Debug, Clone)]
pub struct Network {
    pub scenario: Scenario,
    pub cal: Calibration,
    pub topo: Topology,
}

impl Network {
    /// The paper's single-edge network for `scenario`.
    pub fn new(scenario: Scenario, cal: Calibration) -> Network {
        Network::with_edges(scenario, cal, 1)
    }

    /// Same scenario sharded over `edges` identical edge nodes (every
    /// edge->cloud uplink carries the scenario's E-column condition).
    pub fn with_edges(scenario: Scenario, cal: Calibration, edges: usize) -> Network {
        let topo =
            Topology::uniform(&scenario.device_conds, scenario.edge_cond, edges, cal.vcpus);
        Network { scenario, cal, topo }
    }

    pub fn users(&self) -> usize {
        self.scenario.users()
    }

    /// Fixed message overhead for device `i` executing at `p`, under the
    /// topology table's static link conditions.
    ///
    /// Local execution never uploads the image (paper §3.1: "performance
    /// of the user end device is independent of the network connection"),
    /// so it pays only the update + decision control messages. Edge
    /// execution pays the full request over the device link; cloud
    /// execution additionally pays the full set over the home edge's
    /// edge->cloud hop.
    pub fn path_overhead_ms(&self, device: DeviceId, p: Placement) -> f64 {
        self.path_overhead_ms_with(
            p,
            self.topo.device_cond(device),
            self.topo.edge_cond(self.topo.home_edge(device)),
        )
    }

    /// [`Network::path_overhead_ms`] with the link conditions passed in
    /// explicitly: `dev` is the device's uplink condition and `home_edge`
    /// its home edge's edge->cloud uplink (only read for cloud
    /// execution). This is what lets the response model charge the
    /// *monitored* conditions — which a [`crate::sim::drift::DriftSchedule`]
    /// can change mid-trace — instead of the topology's static table;
    /// when the monitored conds mirror the table (every pre-drift path)
    /// the result is bit-identical.
    pub fn path_overhead_ms_with(&self, p: Placement, dev: NetCond, home_edge: NetCond) -> f64 {
        let ctl = MsgKind::Update.cost_ms(&self.cal, dev)
            + MsgKind::Decision.cost_ms(&self.cal, dev);
        match p {
            Placement::Local => ctl,
            Placement::Edge(_) => ctl + MsgKind::Request.cost_ms(&self.cal, dev),
            Placement::Cloud => {
                let e = home_edge;
                ctl + MsgKind::Request.cost_ms(&self.cal, dev)
                    + MsgKind::Request.cost_ms(&self.cal, e)
                    + MsgKind::Update.cost_ms(&self.cal, e)
                    + MsgKind::Decision.cost_ms(&self.cal, e)
            }
        }
    }

    /// Average extra queueing when `k_shared` requests traverse the same
    /// edge-ingress link simultaneously: the j-th of k serialized
    /// transfers waits (j-1) slots, so the expected extra is
    /// (k-1)/2 * link_queue_ms. Zero for local execution, which bypasses
    /// the ingress entirely.
    pub fn queueing_ms(&self, p: Placement, k_shared: usize) -> f64 {
        if p == Placement::Local || k_shared <= 1 {
            return 0.0;
        }
        (k_shared.saturating_sub(1)) as f64 / 2.0 * self.cal.link_queue_ms
    }

    /// The weak-link packet delta the paper injects (20 ms per egress
    /// packet); exposed for Table 12 regeneration.
    pub fn weak_delta_ms(&self) -> f64 {
        self.cal.request_ms[1] - self.cal.request_ms[0]
    }

    /// Broadcast cost of one resource-monitoring round for device `i`
    /// (Fig 8 overhead accounting).
    pub fn monitor_broadcast_ms(&self, device: DeviceId) -> f64 {
        MsgKind::Update.cost_ms(&self.cal, self.topo.device_cond(device))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::types::Tier;

    fn net(name: &str, users: usize) -> Network {
        Network::new(Scenario::by_name(name, users).unwrap(), Calibration::default())
    }

    #[test]
    fn table12_message_costs() {
        let cal = Calibration::default();
        assert_eq!(MsgKind::Request.cost_ms(&cal, NetCond::Regular), 20.0);
        assert_eq!(MsgKind::Request.cost_ms(&cal, NetCond::Weak), 137.0);
        assert_eq!(MsgKind::Update.cost_ms(&cal, NetCond::Regular), 0.4);
        assert_eq!(MsgKind::Decision.cost_ms(&cal, NetCond::Weak), 2.0);
    }

    #[test]
    fn overhead_regular_totals() {
        let n = net("exp-a", 5);
        // local: control messages only (1.4 ms regular)
        assert!((n.path_overhead_ms(0, Tier::Local) - 1.4).abs() < 1e-9);
        // edge: + request upload = Table 12 total (21.4 ms)
        assert!((n.path_overhead_ms(0, Tier::Edge(0)) - 21.4).abs() < 1e-9);
        // cloud: + the full edge->cloud hop (another 21.4)
        assert!((n.path_overhead_ms(0, Tier::Cloud) - 42.8).abs() < 1e-9);
    }

    #[test]
    fn local_nearly_network_independent() {
        // paper §3.1: device performance independent of network condition
        let r = net("exp-a", 5).path_overhead_ms(0, Tier::Local);
        let w = net("exp-d", 5).path_overhead_ms(0, Tier::Local);
        assert!(w - r < 5.0, "local overhead delta {r} -> {w}");
    }

    #[test]
    fn weak_device_link_dominates() {
        let n = net("exp-d", 5);
        assert!((n.path_overhead_ms(0, Tier::Edge(0)) - 141.0).abs() < 1e-9);
        assert!(n.path_overhead_ms(0, Tier::Cloud) > n.path_overhead_ms(0, Tier::Edge(0)));
    }

    #[test]
    fn mixed_scenario_per_device() {
        let n = net("exp-b", 5); // R W R W R, edge W
        assert!(n.path_overhead_ms(0, Tier::Edge(0)) < n.path_overhead_ms(1, Tier::Edge(0)));
        // cloud path picks up the weak edge hop even for regular devices
        assert!((n.path_overhead_ms(0, Tier::Cloud) - (21.4 + 141.0)).abs() < 1e-9);
    }

    #[test]
    fn queueing_grows_with_offload_count() {
        let n = net("exp-a", 5);
        assert_eq!(n.queueing_ms(Tier::Edge(0), 1), 0.0);
        assert_eq!(n.queueing_ms(Tier::Local, 5), 0.0);
        assert!(n.queueing_ms(Tier::Edge(0), 5) > n.queueing_ms(Tier::Edge(0), 2));
    }

    #[test]
    fn explicit_cond_path_matches_table_conds() {
        // Passing the topology's own conds through the explicit-cond
        // entry must be bitwise the table-driven overhead; flipping the
        // conds moves it by the Table 12 weak deltas.
        let n = net("exp-b", 5); // R W R W R devices, edge W
        for device in 0..5 {
            for p in [Tier::Local, Tier::Edge(0), Tier::Cloud] {
                let table = n.path_overhead_ms(device, p);
                let explicit = n.path_overhead_ms_with(
                    p,
                    n.topo.device_cond(device),
                    n.topo.edge_cond(n.topo.home_edge(device)),
                );
                assert_eq!(table.to_bits(), explicit.to_bits(), "dev {device} {p:?}");
            }
        }
        let weak = n.path_overhead_ms_with(Tier::Edge(0), NetCond::Weak, NetCond::Regular);
        let reg = n.path_overhead_ms_with(Tier::Edge(0), NetCond::Regular, NetCond::Regular);
        assert!(weak > reg + 100.0, "weak uplink must pay the packet delta");
    }

    #[test]
    fn weak_delta_is_paper_emulation() {
        let n = net("exp-a", 1);
        assert_eq!(n.weak_delta_ms(), 117.0); // 137 - 20
    }

    #[test]
    fn multi_edge_topology_homes_devices_round_robin() {
        let n = Network::with_edges(Scenario::exp_a(6), Calibration::default(), 3);
        assert_eq!(n.topo.num_edges(), 3);
        assert_eq!(n.topo.home_edge(0), 0);
        assert_eq!(n.topo.home_edge(5), 2);
        // any edge placement pays the same device uplink cost
        assert_eq!(
            n.path_overhead_ms(0, Placement::Edge(0)),
            n.path_overhead_ms(0, Placement::Edge(2))
        );
        // cloud still pays both hops
        assert!(n.path_overhead_ms(0, Placement::Cloud) > n.path_overhead_ms(0, Placement::Edge(1)));
    }

    #[test]
    fn single_edge_topology_mirrors_scenario() {
        let n = net("exp-b", 5);
        assert_eq!(n.topo.users(), 5);
        assert_eq!(n.topo.num_edges(), 1);
        for i in 0..5 {
            assert_eq!(n.topo.device_cond(i), n.scenario.device_cond(i));
        }
        assert_eq!(n.topo.edge_cond(0), n.scenario.edge_cond);
        assert_eq!(
            [n.topo.devices[0].vcpus, n.topo.edges[0].vcpus, n.topo.cloud.vcpus],
            n.cal.vcpus
        );
    }
}
