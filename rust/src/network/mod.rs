//! Network substrate: per-link conditions (Table 5 scenarios), message
//! costs (Table 12), path overheads per offloading target, and shared-link
//! queueing for simultaneous uploads.
//!
//! Topology (paper Fig 4): each end device S_i has one uplink to the edge;
//! the edge has one uplink to the cloud. Every request is orchestrated by
//! the cloud-hosted Intelligent Orchestrator, so even locally-executed
//! inferences pay the (small) update + decision control messages — but
//! only offloaded ones pay the image-upload request cost, keeping device
//! performance network-independent as the paper observes in §3.1.

use crate::config::{Calibration, Scenario};
use crate::types::{DeviceId, NetCond, Tier};

/// The three framework messages of Table 12.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Input image upload to the executing layer (dominant cost).
    Request,
    /// Resource-monitoring broadcast to the orchestrator.
    Update,
    /// Orchestration decision delivery.
    Decision,
}

impl MsgKind {
    pub fn cost_ms(self, cal: &Calibration, cond: NetCond) -> f64 {
        let i = (cond == NetCond::Weak) as usize;
        match self {
            MsgKind::Request => cal.request_ms[i],
            MsgKind::Update => cal.update_ms[i],
            MsgKind::Decision => cal.decision_ms[i],
        }
    }
}

/// Static network model for one scenario.
#[derive(Debug, Clone)]
pub struct Network {
    pub scenario: Scenario,
    pub cal: Calibration,
}

impl Network {
    pub fn new(scenario: Scenario, cal: Calibration) -> Network {
        Network { scenario, cal }
    }

    pub fn users(&self) -> usize {
        self.scenario.users()
    }

    /// Fixed message overhead for device `i` executing at `tier`.
    ///
    /// Local execution never uploads the image (paper §3.1: "performance
    /// of the user end device is independent of the network connection"),
    /// so it pays only the update + decision control messages. Edge
    /// execution pays the full request over the device link; cloud
    /// execution additionally pays the full set over the edge->cloud hop.
    pub fn path_overhead_ms(&self, device: DeviceId, tier: Tier) -> f64 {
        let dev = self.scenario.device_cond(device);
        let ctl = MsgKind::Update.cost_ms(&self.cal, dev)
            + MsgKind::Decision.cost_ms(&self.cal, dev);
        match tier {
            Tier::Local => ctl,
            Tier::Edge => ctl + MsgKind::Request.cost_ms(&self.cal, dev),
            Tier::Cloud => {
                let e = self.scenario.edge_cond;
                ctl + MsgKind::Request.cost_ms(&self.cal, dev)
                    + MsgKind::Request.cost_ms(&self.cal, e)
                    + MsgKind::Update.cost_ms(&self.cal, e)
                    + MsgKind::Decision.cost_ms(&self.cal, e)
            }
        }
    }

    /// Average extra queueing when `k_offloaded` requests traverse the
    /// shared edge ingress simultaneously: the j-th of k serialized
    /// transfers waits (j-1) slots, so the expected extra is
    /// (k-1)/2 * link_queue_ms. Zero for local execution.
    pub fn queueing_ms(&self, tier: Tier, k_offloaded: usize) -> f64 {
        if tier == Tier::Local || k_offloaded <= 1 {
            return 0.0;
        }
        (k_offloaded.saturating_sub(1)) as f64 / 2.0 * self.cal.link_queue_ms
    }

    /// The weak-link packet delta the paper injects (20 ms per egress
    /// packet); exposed for Table 12 regeneration.
    pub fn weak_delta_ms(&self) -> f64 {
        self.cal.request_ms[1] - self.cal.request_ms[0]
    }

    /// Broadcast cost of one resource-monitoring round for device `i`
    /// (Fig 8 overhead accounting).
    pub fn monitor_broadcast_ms(&self, device: DeviceId) -> f64 {
        MsgKind::Update.cost_ms(&self.cal, self.scenario.device_cond(device))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    fn net(name: &str, users: usize) -> Network {
        Network::new(Scenario::by_name(name, users).unwrap(), Calibration::default())
    }

    #[test]
    fn table12_message_costs() {
        let cal = Calibration::default();
        assert_eq!(MsgKind::Request.cost_ms(&cal, NetCond::Regular), 20.0);
        assert_eq!(MsgKind::Request.cost_ms(&cal, NetCond::Weak), 137.0);
        assert_eq!(MsgKind::Update.cost_ms(&cal, NetCond::Regular), 0.4);
        assert_eq!(MsgKind::Decision.cost_ms(&cal, NetCond::Weak), 2.0);
    }

    #[test]
    fn overhead_regular_totals() {
        let n = net("exp-a", 5);
        // local: control messages only (1.4 ms regular)
        assert!((n.path_overhead_ms(0, Tier::Local) - 1.4).abs() < 1e-9);
        // edge: + request upload = Table 12 total (21.4 ms)
        assert!((n.path_overhead_ms(0, Tier::Edge) - 21.4).abs() < 1e-9);
        // cloud: + the full edge->cloud hop (another 21.4)
        assert!((n.path_overhead_ms(0, Tier::Cloud) - 42.8).abs() < 1e-9);
    }

    #[test]
    fn local_nearly_network_independent() {
        // paper §3.1: device performance independent of network condition
        let r = net("exp-a", 5).path_overhead_ms(0, Tier::Local);
        let w = net("exp-d", 5).path_overhead_ms(0, Tier::Local);
        assert!(w - r < 5.0, "local overhead delta {r} -> {w}");
    }

    #[test]
    fn weak_device_link_dominates() {
        let n = net("exp-d", 5);
        assert!((n.path_overhead_ms(0, Tier::Edge) - 141.0).abs() < 1e-9);
        assert!(n.path_overhead_ms(0, Tier::Cloud) > n.path_overhead_ms(0, Tier::Edge));
    }

    #[test]
    fn mixed_scenario_per_device() {
        let n = net("exp-b", 5); // R W R W R, edge W
        assert!(n.path_overhead_ms(0, Tier::Edge) < n.path_overhead_ms(1, Tier::Edge));
        // cloud path picks up the weak edge hop even for regular devices
        assert!((n.path_overhead_ms(0, Tier::Cloud) - (21.4 + 141.0)).abs() < 1e-9);
    }

    #[test]
    fn queueing_grows_with_offload_count() {
        let n = net("exp-a", 5);
        assert_eq!(n.queueing_ms(Tier::Edge, 1), 0.0);
        assert_eq!(n.queueing_ms(Tier::Local, 5), 0.0);
        assert!(n.queueing_ms(Tier::Edge, 5) > n.queueing_ms(Tier::Edge, 2));
    }

    #[test]
    fn weak_delta_is_paper_emulation() {
        let n = net("exp-a", 1);
        assert_eq!(n.weak_delta_ms(), 117.0); // 137 - 20
    }
}
