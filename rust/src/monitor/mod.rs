//! Resource monitoring: raw per-node utilization snapshots, the paper's
//! Table 3 discretization, and the two state encodings the agents consume —
//! an exact integer key (Q-table rows) and a normalized f32 vector
//! (DQN input, Eq. 3 ordering).

use crate::types::NetCond;

/// Raw utilization snapshot of one node, as the Resource Monitoring
/// service would report it (CPU %, memory %, link condition).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeState {
    /// CPU utilization in [0, 1].
    pub cpu: f64,
    /// Memory utilization in [0, 1].
    pub mem: f64,
    /// Current link condition to the upper layer.
    pub cond: NetCond,
}

impl NodeState {
    pub fn idle(cond: NetCond) -> NodeState {
        NodeState { cpu: 0.0, mem: 0.0, cond }
    }
}

/// Full system snapshot: Eq. 3's S_tau before discretization.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemState {
    pub edge: NodeState,
    pub cloud: NodeState,
    pub devices: Vec<NodeState>,
}

impl SystemState {
    pub fn users(&self) -> usize {
        self.devices.len()
    }
}

// --- Table 3 discretization -------------------------------------------------

/// Edge/cloud CPU levels ("Nine discrete levels").
pub const CPU_LEVELS_EC: usize = 9;
/// Binary levels for everything else.
pub const BINARY: usize = 2;

/// Busy threshold for the binary CPU/memory states.
pub const BUSY_THRESHOLD: f64 = 0.5;

pub fn binary_level(util: f64) -> usize {
    (util > BUSY_THRESHOLD) as usize
}

pub fn cpu_level_ec(util: f64) -> usize {
    ((util * CPU_LEVELS_EC as f64) as usize).min(CPU_LEVELS_EC - 1)
}

fn cond_level(c: NetCond) -> usize {
    (c == NetCond::Weak) as usize
}

/// Discretized + encoded state.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedState {
    /// Exact mixed-radix key over the Table 3 levels (Q-table row id).
    pub key: u64,
    /// Normalized per-component values in Eq. 3 order:
    /// [P^E, M^E, B^E, P^C, M^C, B^C, P^S1, M^S1, B^S1, ...].
    pub vec: Vec<f32>,
}

/// Encode a snapshot per Table 3. The DQN vector carries the *discretized*
/// levels (normalized to [0,1]) so both agents see identical information,
/// as in the paper.
pub fn encode(s: &SystemState) -> EncodedState {
    let mut key: u64 = 0;
    let mut vec = Vec::with_capacity(3 * (s.devices.len() + 2));
    let mut push = |key: &mut u64, vec: &mut Vec<f32>, level: usize, radix: usize| {
        debug_assert!(level < radix);
        *key = *key * radix as u64 + level as u64;
        vec.push(level as f32 / (radix - 1) as f32);
    };
    // Edge
    push(&mut key, &mut vec, cpu_level_ec(s.edge.cpu), CPU_LEVELS_EC);
    push(&mut key, &mut vec, binary_level(s.edge.mem), BINARY);
    push(&mut key, &mut vec, cond_level(s.edge.cond), BINARY);
    // Cloud
    push(&mut key, &mut vec, cpu_level_ec(s.cloud.cpu), CPU_LEVELS_EC);
    push(&mut key, &mut vec, binary_level(s.cloud.mem), BINARY);
    push(&mut key, &mut vec, cond_level(s.cloud.cond), BINARY);
    // End devices
    for d in &s.devices {
        push(&mut key, &mut vec, binary_level(d.cpu), BINARY);
        push(&mut key, &mut vec, binary_level(d.mem), BINARY);
        push(&mut key, &mut vec, cond_level(d.cond), BINARY);
    }
    EncodedState { key, vec }
}

/// |State| per Eq. 5: (2*2*2)^N * (9*2*2)^2.
pub fn state_space_size(users: usize) -> f64 {
    8f64.powi(users as i32) * 36f64.powi(2)
}

/// |State x Action| per Eq. 6 (brute-force complexity, Table 11 column).
pub fn bruteforce_complexity(users: usize, actions_per_device: usize) -> f64 {
    state_space_size(users) * (actions_per_device as f64).powi(users as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use NetCond::{Regular as R, Weak as W};

    fn state(n: usize) -> SystemState {
        SystemState {
            edge: NodeState { cpu: 0.5, mem: 0.2, cond: R },
            cloud: NodeState { cpu: 0.1, mem: 0.8, cond: R },
            devices: (0..n)
                .map(|i| NodeState {
                    cpu: 0.1 * i as f64,
                    mem: 0.0,
                    cond: if i % 2 == 0 { R } else { W },
                })
                .collect(),
        }
    }

    #[test]
    fn discretization_levels() {
        assert_eq!(binary_level(0.4), 0);
        assert_eq!(binary_level(0.6), 1);
        assert_eq!(cpu_level_ec(0.0), 0);
        assert_eq!(cpu_level_ec(0.999), 8);
        assert_eq!(cpu_level_ec(1.0), 8);
        assert_eq!(cpu_level_ec(0.5), 4);
    }

    #[test]
    fn vector_dim_matches_eq3() {
        for n in 1..=5 {
            assert_eq!(encode(&state(n)).vec.len(), 3 * (n + 2));
        }
    }

    #[test]
    fn key_is_injective_on_distinct_levels() {
        let mut a = state(3);
        let e1 = encode(&a);
        a.devices[0].cpu = 0.9; // flips busy bit
        let e2 = encode(&a);
        assert_ne!(e1.key, e2.key);
        assert_ne!(e1.vec, e2.vec);
    }

    #[test]
    fn key_stable_within_level() {
        let mut a = state(3);
        let e1 = encode(&a);
        a.edge.cpu = 0.51; // still level 4 of 9
        let e2 = encode(&a);
        assert_eq!(e1.key, e2.key);
    }

    #[test]
    fn key_below_state_space_size() {
        for n in 1..=5 {
            let e = encode(&state(n));
            assert!((e.key as f64) < state_space_size(n));
        }
    }

    #[test]
    fn vec_normalized() {
        let e = encode(&state(5));
        assert!(e.vec.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn complexity_matches_paper_order() {
        // Paper Table 11 brute-force column grows from ~1e8-1e9 (3 users)
        // to ~1e12 (5 users); the exponential growth is the claim.
        assert!(bruteforce_complexity(5, 24) / bruteforce_complexity(3, 24) > 1e3);
        assert_eq!(state_space_size(5), 8f64.powi(5) * 1296.0);
    }
}
