//! Resource monitoring: raw per-node utilization snapshots, the paper's
//! Table 3 discretization, and the two state encodings the agents consume —
//! an exact integer key (Q-table rows) and a normalized f32 vector
//! (DQN input, Eq. 3 ordering).
//!
//! Snapshots come in two concrete shapes: [`SystemState`] is the paper's
//! fixed single-edge view, [`TopoState`] the N-edge generalization. Both
//! implement [`StateView`], which is what the latency model, the DES core
//! and the encoder consume — so every consumer works for any edge count,
//! and the single-edge path stays bit-identical to the seed.

use crate::types::{NetCond, Topology};

/// Raw utilization snapshot of one node, as the Resource Monitoring
/// service would report it (CPU %, memory %, link condition).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeState {
    /// CPU utilization in [0, 1].
    pub cpu: f64,
    /// Memory utilization in [0, 1].
    pub mem: f64,
    /// Current link condition to the upper layer.
    pub cond: NetCond,
}

impl NodeState {
    pub fn idle(cond: NetCond) -> NodeState {
        NodeState { cpu: 0.0, mem: 0.0, cond }
    }
}

/// Read-only view of the per-node background state of an N-edge topology.
/// Implemented by [`SystemState`] (one edge, the paper's shape) and
/// [`TopoState`] (any edge count).
pub trait StateView {
    fn users(&self) -> usize;
    fn num_edges(&self) -> usize;
    fn device_node(&self, i: usize) -> &NodeState;
    fn edge_node(&self, k: usize) -> &NodeState;
    fn cloud_node(&self) -> &NodeState;
}

/// Full system snapshot in the paper's fixed single-edge shape: Eq. 3's
/// S_tau before discretization.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemState {
    pub edge: NodeState,
    pub cloud: NodeState,
    pub devices: Vec<NodeState>,
}

impl SystemState {
    pub fn users(&self) -> usize {
        self.devices.len()
    }
}

impl StateView for SystemState {
    fn users(&self) -> usize {
        self.devices.len()
    }

    fn num_edges(&self) -> usize {
        1
    }

    fn device_node(&self, i: usize) -> &NodeState {
        &self.devices[i]
    }

    fn edge_node(&self, k: usize) -> &NodeState {
        // hard assert: a multi-edge model paired with the single-edge
        // state shape must fail loudly, not silently read edge 0
        assert_eq!(k, 0, "SystemState has exactly one edge");
        &self.edge
    }

    fn cloud_node(&self) -> &NodeState {
        &self.cloud
    }
}

/// System snapshot over an explicit [`Topology`]: one [`NodeState`] per
/// edge node. The canonical state type for multi-edge networks; with one
/// edge it encodes identically to [`SystemState`].
#[derive(Debug, Clone, PartialEq)]
pub struct TopoState {
    pub edges: Vec<NodeState>,
    pub cloud: NodeState,
    pub devices: Vec<NodeState>,
}

impl TopoState {
    /// All nodes idle, link conditions taken from the topology table.
    pub fn idle(topo: &Topology) -> TopoState {
        TopoState {
            edges: topo.edges.iter().map(|e| NodeState::idle(e.cond)).collect(),
            cloud: NodeState::idle(topo.cloud.cond),
            devices: topo.devices.iter().map(|d| NodeState::idle(d.cond)).collect(),
        }
    }

    pub fn users(&self) -> usize {
        self.devices.len()
    }
}

impl StateView for TopoState {
    fn users(&self) -> usize {
        self.devices.len()
    }

    fn num_edges(&self) -> usize {
        self.edges.len()
    }

    fn device_node(&self, i: usize) -> &NodeState {
        &self.devices[i]
    }

    fn edge_node(&self, k: usize) -> &NodeState {
        &self.edges[k]
    }

    fn cloud_node(&self) -> &NodeState {
        &self.cloud
    }
}

/// Overlay live queue-derived utilization onto a background snapshot: the
/// control plane's mid-trace observation. `load` is per-compute-node
/// utilization in DES node order (each end device, then each edge, then
/// the cloud — [`crate::sim::DesCore::utilization`]); each node's CPU
/// becomes `max(background, live)`, so an idle simulator observes exactly
/// the background state (what pins the single-epoch control loop bitwise
/// to the frozen-snapshot evaluation) while a congested node raises its
/// Table 3 CPU level even when the background Markov state is idle.
pub fn overlay_live_load(base: &TopoState, load: &[f64]) -> TopoState {
    let users = base.devices.len();
    let edges = base.edges.len();
    assert_eq!(load.len(), users + edges + 1, "load vector vs node layout");
    let mut s = base.clone();
    for (i, d) in s.devices.iter_mut().enumerate() {
        d.cpu = d.cpu.max(load[i]);
    }
    for (k, e) in s.edges.iter_mut().enumerate() {
        e.cpu = e.cpu.max(load[users + k]);
    }
    s.cloud.cpu = s.cloud.cpu.max(load[users + edges]);
    s
}

/// Force down nodes to look saturated in the live observation: `down` is
/// per-compute-node health in DES node order (each end device, then each
/// edge, then the cloud — [`crate::sim::DesCore::node_down_mask`]); a
/// down node's CPU is pinned to 1.0, the top Table 3 level, so the
/// encoded state shifts and a value-based policy prices the outage like
/// a saturated queue and routes around it. An all-healthy mask is a
/// strict no-op (what keeps fault-free runs bitwise-pinned).
pub fn mask_down_nodes(state: &mut TopoState, down: &[bool]) {
    let users = state.devices.len();
    let edges = state.edges.len();
    assert_eq!(down.len(), users + edges + 1, "down mask vs node layout");
    for (i, d) in state.devices.iter_mut().enumerate() {
        if down[i] {
            d.cpu = 1.0;
        }
    }
    for (k, e) in state.edges.iter_mut().enumerate() {
        if down[users + k] {
            e.cpu = 1.0;
        }
    }
    if down[users + edges] {
        state.cloud.cpu = 1.0;
    }
}

// --- Table 3 discretization -------------------------------------------------

/// Edge/cloud CPU levels ("Nine discrete levels").
pub const CPU_LEVELS_EC: usize = 9;
/// Binary levels for everything else.
pub const BINARY: usize = 2;

/// Busy threshold for the binary CPU/memory states.
pub const BUSY_THRESHOLD: f64 = 0.5;

pub fn binary_level(util: f64) -> usize {
    (util > BUSY_THRESHOLD) as usize
}

pub fn cpu_level_ec(util: f64) -> usize {
    ((util * CPU_LEVELS_EC as f64) as usize).min(CPU_LEVELS_EC - 1)
}

fn cond_level(c: NetCond) -> usize {
    (c == NetCond::Weak) as usize
}

/// Discretized + encoded state.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedState {
    /// Exact mixed-radix key over the Table 3 levels (Q-table row id).
    pub key: u64,
    /// Normalized per-component values in Eq. 3 order:
    /// [P^E1, M^E1, B^E1, ..., P^C, M^C, B^C, P^S1, M^S1, B^S1, ...].
    pub vec: Vec<f32>,
}

/// Encode a snapshot per Table 3: each edge node (in id order), then the
/// cloud, then the end devices. The DQN vector carries the *discretized*
/// levels (normalized to [0,1]) so both agents see identical information,
/// as in the paper. For a single edge this is byte-identical to the
/// pre-topology encoding.
pub fn encode<S: StateView>(s: &S) -> EncodedState {
    let mut key: u64 = 0;
    let mut vec = Vec::with_capacity(3 * (s.users() + 1 + s.num_edges()));
    let mut push = |key: &mut u64, vec: &mut Vec<f32>, level: usize, radix: usize| {
        debug_assert!(level < radix);
        *key = *key * radix as u64 + level as u64;
        vec.push(level as f32 / (radix - 1) as f32);
    };
    // Edge nodes
    for k in 0..s.num_edges() {
        let e = s.edge_node(k);
        push(&mut key, &mut vec, cpu_level_ec(e.cpu), CPU_LEVELS_EC);
        push(&mut key, &mut vec, binary_level(e.mem), BINARY);
        push(&mut key, &mut vec, cond_level(e.cond), BINARY);
    }
    // Cloud
    let c = s.cloud_node();
    push(&mut key, &mut vec, cpu_level_ec(c.cpu), CPU_LEVELS_EC);
    push(&mut key, &mut vec, binary_level(c.mem), BINARY);
    push(&mut key, &mut vec, cond_level(c.cond), BINARY);
    // End devices
    for i in 0..s.users() {
        let d = s.device_node(i);
        push(&mut key, &mut vec, binary_level(d.cpu), BINARY);
        push(&mut key, &mut vec, binary_level(d.mem), BINARY);
        push(&mut key, &mut vec, cond_level(d.cond), BINARY);
    }
    EncodedState { key, vec }
}

/// |State| per Eq. 5 for the paper's single-edge network:
/// (2*2*2)^N * (9*2*2)^2.
pub fn state_space_size(users: usize) -> f64 {
    state_space_size_for(users, 1)
}

/// |State| generalized to `edges` edge nodes: 8^N * 36^(edges + 1).
pub fn state_space_size_for(users: usize, edges: usize) -> f64 {
    8f64.powi(users as i32) * 36f64.powi(edges as i32 + 1)
}

/// |State x Action| per Eq. 6 (brute-force complexity, Table 11 column).
pub fn bruteforce_complexity(users: usize, actions_per_device: usize) -> f64 {
    state_space_size(users) * (actions_per_device as f64).powi(users as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use NetCond::{Regular as R, Weak as W};

    fn state(n: usize) -> SystemState {
        SystemState {
            edge: NodeState { cpu: 0.5, mem: 0.2, cond: R },
            cloud: NodeState { cpu: 0.1, mem: 0.8, cond: R },
            devices: (0..n)
                .map(|i| NodeState {
                    cpu: 0.1 * i as f64,
                    mem: 0.0,
                    cond: if i % 2 == 0 { R } else { W },
                })
                .collect(),
        }
    }

    #[test]
    fn discretization_levels() {
        assert_eq!(binary_level(0.4), 0);
        assert_eq!(binary_level(0.6), 1);
        assert_eq!(cpu_level_ec(0.0), 0);
        assert_eq!(cpu_level_ec(0.999), 8);
        assert_eq!(cpu_level_ec(1.0), 8);
        assert_eq!(cpu_level_ec(0.5), 4);
    }

    #[test]
    fn vector_dim_matches_eq3() {
        for n in 1..=5 {
            assert_eq!(encode(&state(n)).vec.len(), 3 * (n + 2));
        }
    }

    #[test]
    fn key_is_injective_on_distinct_levels() {
        let mut a = state(3);
        let e1 = encode(&a);
        a.devices[0].cpu = 0.9; // flips busy bit
        let e2 = encode(&a);
        assert_ne!(e1.key, e2.key);
        assert_ne!(e1.vec, e2.vec);
    }

    #[test]
    fn key_stable_within_level() {
        let mut a = state(3);
        let e1 = encode(&a);
        a.edge.cpu = 0.51; // still level 4 of 9
        let e2 = encode(&a);
        assert_eq!(e1.key, e2.key);
    }

    #[test]
    fn key_below_state_space_size() {
        for n in 1..=5 {
            let e = encode(&state(n));
            assert!((e.key as f64) < state_space_size(n));
        }
    }

    #[test]
    fn vec_normalized() {
        let e = encode(&state(5));
        assert!(e.vec.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn complexity_matches_paper_order() {
        // Paper Table 11 brute-force column grows from ~1e8-1e9 (3 users)
        // to ~1e12 (5 users); the exponential growth is the claim.
        assert!(bruteforce_complexity(5, 24) / bruteforce_complexity(3, 24) > 1e3);
        assert_eq!(state_space_size(5), 8f64.powi(5) * 1296.0);
    }

    #[test]
    fn single_edge_topo_state_encodes_like_system_state() {
        let s = state(4);
        let t = TopoState {
            edges: vec![s.edge],
            cloud: s.cloud,
            devices: s.devices.clone(),
        };
        assert_eq!(encode(&s), encode(&t));
    }

    #[test]
    fn live_load_overlay_is_max_merge() {
        let topo = Topology::uniform(&[R, R, R], W, 1, [1, 2, 4]);
        let mut base = TopoState::idle(&topo);
        base.devices[1].cpu = 0.7; // background busier than live
        // zero live load observes exactly the background state
        let idle = overlay_live_load(&base, &[0.0; 5]);
        assert_eq!(idle, base);
        assert_eq!(encode(&idle), encode(&base));
        // live congestion raises the observed level without touching mem
        let hot = overlay_live_load(&base, &[1.0, 0.2, 0.0, 0.5, 0.25]);
        assert_eq!(hot.devices[0].cpu, 1.0);
        assert_eq!(hot.devices[1].cpu, 0.7, "background wins when busier");
        assert_eq!(hot.edges[0].cpu, 0.5);
        assert_eq!(hot.cloud.cpu, 0.25);
        assert_eq!(hot.devices[0].mem, base.devices[0].mem);
        assert_ne!(encode(&hot).key, encode(&base).key);
    }

    #[test]
    fn down_mask_saturates_only_down_nodes() {
        let topo = Topology::uniform(&[R, R, R], W, 1, [1, 2, 4]);
        let base = TopoState::idle(&topo);
        // all-healthy mask: bitwise no-op
        let mut s = base.clone();
        mask_down_nodes(&mut s, &[false; 5]);
        assert_eq!(s, base);
        assert_eq!(encode(&s), encode(&base));
        // edge down: its CPU pins to the top level, nothing else moves
        let mut s = base.clone();
        mask_down_nodes(&mut s, &[false, false, false, true, false]);
        assert_eq!(s.edges[0].cpu, 1.0);
        assert_eq!(cpu_level_ec(s.edges[0].cpu), CPU_LEVELS_EC - 1);
        assert_eq!(s.devices, base.devices);
        assert_eq!(s.cloud, base.cloud);
        assert_ne!(encode(&s).key, encode(&base).key);
        // cloud down
        let mut s = base.clone();
        mask_down_nodes(&mut s, &[false, false, false, false, true]);
        assert_eq!(s.cloud.cpu, 1.0);
    }

    #[test]
    #[should_panic(expected = "down mask vs node layout")]
    fn down_mask_rejects_wrong_arity() {
        let topo = Topology::uniform(&[R, R], R, 1, [1, 2, 4]);
        let mut base = TopoState::idle(&topo);
        mask_down_nodes(&mut base, &[false; 3]);
    }

    #[test]
    #[should_panic(expected = "load vector vs node layout")]
    fn live_load_overlay_rejects_wrong_arity() {
        let topo = Topology::uniform(&[R, R], R, 1, [1, 2, 4]);
        let base = TopoState::idle(&topo);
        let _ = overlay_live_load(&base, &[0.0; 3]);
    }

    #[test]
    fn multi_edge_encoding_grows_and_separates_edges() {
        let topo = Topology::uniform(&[R, W, R], W, 3, [1, 2, 4]);
        let mut t = TopoState::idle(&topo);
        let e = encode(&t);
        assert_eq!(e.vec.len(), 3 * (3 + 1 + 3));
        assert!((e.key as f64) < state_space_size_for(3, 3));
        let k0 = e.key;
        t.edges[2].cpu = 0.9; // distinct edge -> distinct key
        assert_ne!(encode(&t).key, k0);
    }
}
