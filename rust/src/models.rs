//! The MobileNetV1 model catalog (paper Table 4).
//!
//! MAC counts here are the paper's (569/317/150/41 MMACs at 224x224); the
//! simulator's latency model is calibrated against these. The runtime
//! cross-checks this catalog against `artifacts/manifest.json` (whose MACs
//! are recomputed for our 64x64 geometry but keep the same ratios).

use crate::types::{ModelId, NUM_MODELS};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    Fp32,
    Int8,
}

#[derive(Debug, Clone, Copy)]
pub struct ModelInfo {
    pub id: ModelId,
    /// Width multiplier (1.0 / 0.75 / 0.5 / 0.25).
    pub alpha: f64,
    pub precision: Precision,
    /// Million MACs at the paper's 224x224 geometry (Table 4).
    pub mmacs: f64,
    pub top1: f64,
    pub top5: f64,
}

/// Table 4 verbatim.
pub const CATALOG: [ModelInfo; NUM_MODELS] = [
    ModelInfo { id: ModelId(0), alpha: 1.00, precision: Precision::Fp32, mmacs: 569.0, top1: 70.9, top5: 89.9 },
    ModelInfo { id: ModelId(1), alpha: 0.75, precision: Precision::Fp32, mmacs: 317.0, top1: 68.4, top5: 88.2 },
    ModelInfo { id: ModelId(2), alpha: 0.50, precision: Precision::Fp32, mmacs: 150.0, top1: 63.3, top5: 84.9 },
    ModelInfo { id: ModelId(3), alpha: 0.25, precision: Precision::Fp32, mmacs: 41.0, top1: 49.8, top5: 74.2 },
    ModelInfo { id: ModelId(4), alpha: 1.00, precision: Precision::Int8, mmacs: 569.0, top1: 70.1, top5: 88.9 },
    ModelInfo { id: ModelId(5), alpha: 0.75, precision: Precision::Int8, mmacs: 317.0, top1: 66.8, top5: 87.0 },
    ModelInfo { id: ModelId(6), alpha: 0.50, precision: Precision::Int8, mmacs: 150.0, top1: 60.7, top5: 83.2 },
    ModelInfo { id: ModelId(7), alpha: 0.25, precision: Precision::Int8, mmacs: 41.0, top1: 48.0, top5: 72.8 },
];

pub fn info(id: ModelId) -> &'static ModelInfo {
    &CATALOG[id.index()]
}

/// Top-5 accuracies indexed by model (used by Decision::avg_accuracy).
pub fn top5_table() -> [f64; NUM_MODELS] {
    let mut t = [0.0; NUM_MODELS];
    for m in &CATALOG {
        t[m.id.index()] = m.top5;
    }
    t
}

/// Highest-accuracy model (d0) — what the SOTA baseline and fixed
/// strategies always deploy (paper §6).
pub const MOST_ACCURATE: ModelId = ModelId(0);

/// Maximum achievable average top-5 accuracy (all-d0).
pub const MAX_ACCURACY: f64 = 89.9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table4() {
        assert_eq!(CATALOG.len(), 8);
        assert_eq!(info(ModelId(0)).mmacs, 569.0);
        assert_eq!(info(ModelId(3)).mmacs, 41.0);
        assert_eq!(info(ModelId(7)).top5, 72.8);
        assert_eq!(info(ModelId(4)).precision, Precision::Int8);
    }

    #[test]
    fn accuracy_monotone_within_precision() {
        for base in [0usize, 4] {
            for i in base..base + 3 {
                assert!(CATALOG[i].top5 > CATALOG[i + 1].top5);
                assert!(CATALOG[i].mmacs >= CATALOG[i + 1].mmacs);
            }
        }
    }

    #[test]
    fn int8_variant_loses_accuracy_vs_fp32() {
        for i in 0..4 {
            assert!(CATALOG[i].top5 > CATALOG[i + 4].top5);
            assert_eq!(CATALOG[i].alpha, CATALOG[i + 4].alpha);
        }
    }

    #[test]
    fn top5_table_indexed_correctly() {
        let t = top5_table();
        assert_eq!(t[0], 89.9);
        assert_eq!(t[7], 72.8);
        assert_eq!(t[0], MAX_ACCURACY);
    }
}
