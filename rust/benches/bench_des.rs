//! DES core throughput benches: the event queue is the substrate every
//! open-loop evaluation and future scale experiment (admission control,
//! autoscaling, sharding) runs on, so events/second is a first-class
//! budget. Also covers arrival-schedule generation and the sync-round
//! adapter the RL training loop now goes through.
//!
//! `open_loop_10u_60s_poisson2` measures the production hot path — a
//! reused [`eeco::sim::DesCore`] (memoized service tables, no per-call
//! allocation); `open_loop_10u_60s_fresh_alloc` keeps the one-shot
//! wrapper measured so the arena's win stays visible across PRs
//! (BENCH_des.json tracks both).

use eeco::prelude::*;
use eeco::sim::arrivals::{schedule, ArrivalProcess};
use eeco::sim::des;
use eeco::sim::ResponseModel;
use eeco::util::bench::Bench;

fn main() {
    let mut b = Bench::new("des");

    let users = 10;
    let model = ResponseModel::new(eeco::network::Network::new(
        Scenario::exp_a(users),
        Calibration::default(),
    ));
    let state = eeco::monitor::SystemState {
        edge: eeco::monitor::NodeState::idle(NetCond::Regular),
        cloud: eeco::monitor::NodeState::idle(NetCond::Regular),
        devices: vec![eeco::monitor::NodeState::idle(NetCond::Regular); users],
    };
    let decision = Decision(
        (0..users)
            .map(|i| Action {
                placement: Tier::from_index(i % 3),
                model: ModelId((i % 8) as u8),
            })
            .collect(),
    );

    b.run("schedule_poisson_10u_60s", || {
        schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 60_000.0, 1).len()
    });

    let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 60_000.0, 1);
    println!("  (open-loop trace: {} requests)", trace.len());
    let mut core = des::DesCore::new();
    core.install(&model, &state);
    let mut out = des::DesOutcome::default();
    b.run("open_loop_10u_60s_poisson2", || {
        core.run_open_loop_into(&decision, &trace, 60_000.0, 2, &mut out);
        out.completed.len()
    });
    b.run("open_loop_10u_60s_fresh_alloc", || {
        des::run_open_loop(&model, &state, &decision, &trace, 60_000.0, 2).completed.len()
    });

    // Control-plane overhead probe: the same trace through the sliced
    // driver with a 5 s control period (12 ticks) — the cost of pausable
    // virtual time vs the monolithic run above.
    b.run("open_loop_10u_60s_12ticks", || {
        core.run_sliced(&decision, &trace, 60_000.0, 5_000.0, 2, &mut out);
        out.completed.len()
    });

    let burst = schedule(
        ArrivalProcess::Mmpp { calm_rate_per_s: 0.5, burst_rate_per_s: 6.0, mean_phase_ms: 2000.0 },
        users,
        60_000.0,
        3,
    );
    b.run("open_loop_10u_60s_mmpp", || {
        core.run_open_loop_into(&decision, &burst, 60_000.0, 4, &mut out);
        out.completed.len()
    });

    // Million-request-scale budget probe: 50 devices x 4 req/s x 500 s
    // ~ 100k requests per iteration through a reused core.
    let big_users = 50;
    let big_model = ResponseModel::new(eeco::network::Network::new(
        Scenario::exp_a(big_users),
        Calibration::default(),
    ));
    let big_state = eeco::monitor::TopoState::idle(&big_model.net.topo);
    let big_decision = Decision(
        (0..big_users)
            .map(|i| Action {
                placement: Tier::from_index(i % 3),
                model: ModelId((i % 8) as u8),
            })
            .collect(),
    );
    let big_trace =
        schedule(ArrivalProcess::Poisson { rate_per_s: 4.0 }, big_users, 500_000.0, 5);
    println!("  (100k trace: {} requests)", big_trace.len());
    let mut big_core = des::DesCore::new();
    big_core.install(&big_model, &big_state);
    b.run("open_loop_100k_requests_50u", || {
        big_core.run_open_loop_into(&big_decision, &big_trace, 500_000.0, 6, &mut out);
        out.completed.len()
    });

    // Sharded-engine scale series: 2000 users x 1 req/s x 500 s ~ 1M
    // requests per iteration, streamed (never materialized) through
    // `ShardedDes` at increasing shard counts on an 8-edge topology —
    // the events/sec/shard budget the `scale` experiment reports in
    // virtual time, measured here in wall time. `open_loop_1m_requests_
    // sharded` is the headline all-shards row; the `_Nx` series keeps
    // the scaling curve visible across PRs.
    let shard_users = 2_000;
    let shard_edges = 8;
    let shard_model = ResponseModel::new(eeco::network::Network::with_edges(
        Scenario::exp_a(shard_users),
        Calibration::default(),
        shard_edges,
    ));
    let shard_state = eeco::monitor::TopoState::idle(&shard_model.net.topo);
    // Domain-local mix (the sharded engine's contract): 1% cloud, 1%
    // home edge, the rest on-device, everyone on the cheapest model.
    let shard_decision = Decision(
        (0..shard_users)
            .map(|d| Action {
                placement: match d % 100 {
                    0 => Tier::Cloud,
                    1 => Tier::Edge(d % shard_edges),
                    _ => Tier::Local,
                },
                model: ModelId(3),
            })
            .collect(),
    );
    let shard_pool = eeco::util::pool::ThreadPool::new(
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(shard_edges),
        "bench-shard",
    );
    for shards in [1usize, 2, 4] {
        let name = format!("open_loop_1m_requests_sharded_{shards}x");
        b.run(&name, || {
            eeco::sim::run_sharded_open_loop(
                &shard_model,
                &shard_state,
                &shard_decision,
                ArrivalProcess::Poisson { rate_per_s: 1.0 },
                500_000.0,
                9,
                10,
                &eeco::sim::DriftSchedule::none(),
                eeco::sim::ShardPlan { shards, ..Default::default() },
                if shards > 1 { Some(&shard_pool) } else { None },
            )
            .summary
            .completed
        });
    }
    b.run("open_loop_1m_requests_sharded", || {
        eeco::sim::run_sharded_open_loop(
            &shard_model,
            &shard_state,
            &shard_decision,
            ArrivalProcess::Poisson { rate_per_s: 1.0 },
            500_000.0,
            9,
            10,
            &eeco::sim::DriftSchedule::none(),
            eeco::sim::ShardPlan { shards: shard_edges, ..Default::default() },
            Some(&shard_pool),
        )
        .summary
        .completed
    });

    // Scheduler comparison at the 1M-request volume: the same serial
    // workload through the BinaryHeap reference and the timing wheel.
    // Outcomes are property-pinned bitwise identical, so the only
    // difference is queue cost — the BENCH_des.json pair the `[perf]`
    // scheduler decision is judged on.
    for sched in [eeco::sim::SchedulerKind::Heap, eeco::sim::SchedulerKind::Wheel] {
        let name = format!("open_loop_1m_requests_{}", sched.label());
        b.run(&name, || {
            eeco::sim::run_sharded_open_loop(
                &shard_model,
                &shard_state,
                &shard_decision,
                ArrivalProcess::Poisson { rate_per_s: 1.0 },
                500_000.0,
                9,
                10,
                &eeco::sim::DriftSchedule::none(),
                eeco::sim::ShardPlan { shards: 1, window_ms: 0.0, sched, ..Default::default() },
                None,
            )
            .summary
            .completed
        });
    }

    // Control-plane fast path: the same frozen 60 s online drift run
    // (240 control ticks) with the decision memo on vs off — outcomes
    // are property-pinned bitwise identical, so the pair isolates the
    // per-tick decide cost the `[perf] decision_cache` default buys back.
    let ol_users = 10;
    let ol_drift = eeco::sim::DriftSchedule::parse("20000:rate=2,net=weak;40000:rate=1,net=regular")
        .expect("static drift spec parses");
    for (name, cache) in [("online_drift_60s_cache_on", 512usize), ("online_drift_60s_cache_off", 0)]
    {
        b.run(name, || {
            let env = eeco::sim::Env::new(
                Scenario::exp_a(ol_users),
                Calibration::default(),
                AccuracyConstraint::Max,
                11,
            );
            let mut orch = eeco::orchestrator::Orchestrator::new(
                env,
                Box::new(eeco::agent::baseline::FixedAgent::new(Tier::Cloud, ol_users)),
            );
            orch.decision_cache = cache;
            orch.env.freeze();
            orch.env.reset_load();
            let ctl =
                eeco::orchestrator::ControlCfg { period_ms: 250.0, online_learning: false };
            orch.evaluate_chaos(
                ArrivalProcess::Poisson { rate_per_s: 2.0 },
                60_000.0,
                12,
                &ctl,
                &ol_drift,
                &eeco::config::AdmissionConfig::default(),
                &eeco::sim::FaultPlan::none(),
            )
            .outcome
            .completed
            .len()
        });
    }

    // Admission-path overhead probe: a 50-user trace well past saturation
    // through the deadline-shed ingress (per-arrival predicted-completion
    // check + shed accounting) at a 5 s control period. Compare against
    // the unpoliced series above to keep the admission tax visible.
    let mut overload_trace =
        schedule(ArrivalProcess::Poisson { rate_per_s: 8.0 }, big_users, 60_000.0, 7);
    eeco::sim::admission::stamp_deadlines(&mut overload_trace, &big_core, 0.0, 3.0);
    println!("  (overload trace: {} requests)", overload_trace.len());
    let mut shed_policy = eeco::sim::DeadlineShed;
    b.run("open_loop_50u_overload_shed", || {
        big_core.run_admitted(
            &big_decision,
            &overload_trace,
            60_000.0,
            5_000.0,
            &mut shed_policy,
            8,
            &mut out,
        );
        out.completed.len() + out.shed
    });

    // The per-training-round adapter, on its allocation-free scratch path.
    let mut scratch = des::SyncScratch::new();
    let mut responses = Vec::new();
    b.run("sync_round_adapter_n10", || {
        des::sync_round_responses_into(&model, &decision, &state, &mut scratch, &mut responses);
        responses.len()
    });

    b.save();
}
