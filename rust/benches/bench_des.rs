//! DES core throughput benches: the event queue is the substrate every
//! open-loop evaluation and future scale experiment (admission control,
//! autoscaling, sharding) runs on, so events/second is a first-class
//! budget. Also covers arrival-schedule generation and the sync-round
//! adapter the RL training loop now goes through.

use eeco::prelude::*;
use eeco::sim::arrivals::{schedule, ArrivalProcess};
use eeco::sim::des;
use eeco::sim::ResponseModel;
use eeco::util::bench::Bench;

fn main() {
    let mut b = Bench::new("des");

    let users = 10;
    let model = ResponseModel::new(eeco::network::Network::new(
        Scenario::exp_a(users),
        Calibration::default(),
    ));
    let state = eeco::monitor::SystemState {
        edge: eeco::monitor::NodeState::idle(NetCond::Regular),
        cloud: eeco::monitor::NodeState::idle(NetCond::Regular),
        devices: vec![eeco::monitor::NodeState::idle(NetCond::Regular); users],
    };
    let decision = Decision(
        (0..users)
            .map(|i| Action {
                placement: Tier::from_index(i % 3),
                model: ModelId((i % 8) as u8),
            })
            .collect(),
    );

    b.run("schedule_poisson_10u_60s", || {
        schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 60_000.0, 1).len()
    });

    let trace = schedule(ArrivalProcess::Poisson { rate_per_s: 2.0 }, users, 60_000.0, 1);
    println!("  (open-loop trace: {} requests)", trace.len());
    b.run("open_loop_10u_60s_poisson2", || {
        des::run_open_loop(&model, &state, &decision, &trace, 60_000.0, 2).completed.len()
    });

    let burst = schedule(
        ArrivalProcess::Mmpp { calm_rate_per_s: 0.5, burst_rate_per_s: 6.0, mean_phase_ms: 2000.0 },
        users,
        60_000.0,
        3,
    );
    b.run("open_loop_10u_60s_mmpp", || {
        des::run_open_loop(&model, &state, &decision, &burst, 60_000.0, 4).completed.len()
    });

    b.run("sync_round_adapter_n10", || {
        des::sync_round_responses(&model, &decision, &state)
    });

    b.save();
}
