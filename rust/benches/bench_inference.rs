//! PJRT inference latency per model/batch — the measured-mode compute
//! substrate behind Tables 8/9 and the calibration of ms/MMAC. Requires
//! `make artifacts`.

use eeco::prelude::*;
use eeco::sim::workload::synth_image;
use eeco::util::bench::Bench;

fn main() {
    let art = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(&format!("{art}/manifest.json")).exists() {
        println!("artifacts missing: run `make artifacts` first");
        return;
    }
    let rt = eeco::runtime::shared(art);
    let (h, w, c) = rt.manifest.img;
    let mut b = Bench::new("inference");

    // batch-1 latency across the full catalog (d0..d7): latency should
    // track MACs (d0 > d1 > d2 > d3) with d4..d7 matching their fp32 twins
    // (the int8 speedup is modeled in sim; see DESIGN.md substitution 3).
    let img = synth_image(0, h, w, c);
    for m in ModelId::all() {
        rt.infer(m, &img, 1).unwrap(); // compile + warm
        b.run(&format!("mobilenet_{m}_b1"), || rt.infer(m, &img, 1).unwrap());
    }

    // batching efficiency: per-image cost at batch 8 vs 1 (dynamic batcher
    // motivation).
    let imgs8: Vec<f32> = (0..8).flat_map(|i| synth_image(i, h, w, c)).collect();
    for m in [ModelId(0), ModelId(3)] {
        rt.infer(m, &imgs8, 8).unwrap();
        b.run(&format!("mobilenet_{m}_b8"), || rt.infer(m, &imgs8, 8).unwrap());
    }

    // DQN graphs
    for users in [3usize, 5] {
        let theta = rt.dqn_init(users).unwrap();
        let d = rt.manifest.dqn_for(users).unwrap().clone();
        let state = vec![0.5f32; d.state_dim];
        rt.dqn_forward(users, &theta, &state).unwrap();
        b.run(&format!("dqn_forward_n{users}"), || {
            rt.dqn_forward(users, &theta, &state).unwrap()
        });
        let bsz = d.train_batch;
        let s = vec![0.5f32; bsz * d.state_dim];
        let mut a = vec![0f32; bsz * users * d.actions_per_device];
        for bi in 0..bsz {
            for dev in 0..users {
                a[bi * users * d.actions_per_device + dev * d.actions_per_device] = 1.0;
            }
        }
        let r = vec![-0.5f32; bsz];
        rt.dqn_train(users, &theta, &s, &a, &r, &s, 1e-3).unwrap();
        b.run(&format!("dqn_train_step_n{users}"), || {
            rt.dqn_train(users, &theta, &s, &a, &r, &s, 1e-3).unwrap().1
        });
    }

    b.save();
}
