//! Agent-step latency benches (paper §6.2.2: QL logic 0.6 ms on the cloud
//! CPU; DQL step 11 ms on an RTX 5000 — ours runs the DQL network through
//! PJRT CPU). Also covers the brute-force oracle (the "impractical" search
//! the paper motivates against) and the replay buffer.

use eeco::agent::qlearning::QTableAgent;
use eeco::agent::replay::{ReplayBuffer, Transition};
use eeco::agent::{bruteforce, ActionSet, Agent};
use eeco::prelude::*;
use eeco::sim::Env;
use eeco::util::bench::Bench;

fn main() {
    let mut b = Bench::new("agent");

    // --- Q-Learning decide + learn (paper: 0.6 ms/step) ---
    for users in [3usize, 5] {
        let hyper = Hyper::paper_defaults(Algo::QLearning, users);
        let mut agent = QTableAgent::new(users, hyper, ActionSet::full(), 1);
        let mut env = Env::new(Scenario::exp_a(users), Calibration::default(), AccuracyConstraint::Max, 2);
        // pre-train briefly so tables are warm
        for _ in 0..1000 {
            let s = env.encoded();
            let d = agent.decide(&s, true);
            let out = env.step(&d);
            let s2 = env.encoded();
            agent.learn(&s, &d, out.reward, &s2);
        }
        let s = env.encoded();
        b.run(&format!("qlearning_decide_greedy_n{users}"), || agent.decide(&s, false));
        let d = agent.decide(&s, false);
        b.run(&format!("qlearning_full_step_n{users}"), || {
            let s0 = env.encoded();
            let out = env.step(&d);
            let s1 = env.encoded();
            agent.learn(&s0, &d, out.reward, &s1);
        });
    }

    // --- DQN decide/train via PJRT (needs artifacts) ---
    let art = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{art}/manifest.json")).exists() {
        let rt = std::sync::Arc::new(eeco::runtime::SharedRuntime::load(art).unwrap());
        for users in [3usize, 5] {
            let hyper = Hyper::paper_defaults(Algo::Dqn, users);
            let mut agent =
                eeco::agent::dqn::DqnAgent::new(users, hyper, rt.clone(), 3).unwrap();
            let mut env = Env::new(
                Scenario::exp_a(users),
                Calibration::default(),
                AccuracyConstraint::Max,
                4,
            );
            // warm the replay buffer past one minibatch
            for _ in 0..80 {
                let s = env.encoded();
                let d = agent.decide(&s, true);
                let out = env.step(&d);
                let s2 = env.encoded();
                agent.learn(&s, &d, out.reward, &s2);
            }
            let s = env.encoded();
            b.run(&format!("dqn_decide_fwd_n{users}"), || agent.decide(&s, false));
            let d = agent.decide(&s, false);
            b.run(&format!("dqn_full_step_train_n{users}"), || {
                let s0 = env.encoded();
                let out = env.step(&d);
                let s1 = env.encoded();
                agent.learn(&s0, &d, out.reward, &s1);
            });
        }
    } else {
        println!("  (artifacts missing: DQN benches skipped)");
    }

    // --- brute-force oracle cost (Eq. 5/6 motivation) ---
    for users in [3usize, 5] {
        let env = Env::new(Scenario::exp_b(users), Calibration::default(), AccuracyConstraint::AtLeast(85.0), 5);
        b.run(&format!("bruteforce_oracle_n{users}"), || {
            bruteforce::optimal(&env, 85.0).unwrap().1
        });
    }

    // --- replay buffer ops ---
    let mut buf = ReplayBuffer::new(1000);
    let t = Transition { state: vec![0.0; 21], actions: vec![0; 5], reward: -1.0, next_state: vec![0.0; 21] };
    b.run("replay_push", || buf.push(t.clone()));
    let mut rng = Rng::new(6);
    b.run("replay_sample_64", || buf.sample(64, &mut rng).len());

    b.save();
}
