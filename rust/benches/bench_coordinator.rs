//! Coordinator hot-path benches: router, batcher, state encoding, message
//! cost lookups — the L3 pieces that must never dominate a ~ms decision
//! loop (paper overhead analysis §6.2.2).

use eeco::coordinator::{Batcher, Router};
use eeco::monitor::{self, NodeState, SystemState};
use eeco::network::Network;
use eeco::prelude::*;
use eeco::util::bench::Bench;

fn main() {
    let mut b = Bench::new("coordinator");

    let users = 5;
    let decision = Decision(
        (0..users).map(|i| Action::from_index((i * 5) % ACTIONS_PER_DEVICE)).collect(),
    );
    let router = Router::new(decision.clone());
    b.run("router_route_single", || router.route(7, 3));
    let reqs: Vec<eeco::sim::Request> = (0..users)
        .map(|d| eeco::sim::Request::at(d as u64, d, 0.0))
        .collect();
    b.run("router_route_round_n5", || router.route_round(&reqs));

    let mut batcher = Batcher::new(8, 4.0);
    let mut i = 0u64;
    b.run("batcher_push_poll", || {
        i += 1;
        let _ = batcher.push(ModelId((i % 8) as u8), i, i as f64);
        batcher.poll(i as f64).len()
    });

    let sys = SystemState {
        edge: NodeState { cpu: 0.4, mem: 0.2, cond: NetCond::Regular },
        cloud: NodeState { cpu: 0.1, mem: 0.1, cond: NetCond::Regular },
        devices: vec![NodeState::idle(NetCond::Weak); users],
    };
    b.run("state_encode_n5", || monitor::encode(&sys));

    let net = Network::new(Scenario::exp_b(users), Calibration::default());
    b.run("network_path_overhead", || {
        let mut acc = 0.0;
        for d in 0..users {
            for t in Tier::ALL {
                acc += net.path_overhead_ms(d, t);
            }
        }
        acc
    });

    b.save();
}
