//! Raw event-queue microbench: push/pop throughput of the two `[perf]`
//! scheduler implementations (`BinaryHeap` reference vs hierarchical
//! timing wheel) on synthetic event streams, isolated from the DES
//! engines. Two access patterns bound the design space: `hold` pushes
//! the whole horizon up front then drains (worst case for the heap's
//! O(log n) at full depth), `churn` interleaves push/pop at a small
//! steady-state depth (the DES regime — every pop schedules a successor
//! slightly in the future). 10^6 events per iteration in both.

use std::cmp::Ordering;

use eeco::sim::{EventQueue, SchedEvent, SchedulerKind, WheelGranularity};
use eeco::util::bench::Bench;
use eeco::util::rng::Rng;

/// Minimal schedulable event: the DES comparator (inverted for the
/// max-heap, seq tiebreak) over a bare (time, seq) pair.
#[derive(Debug, Clone, Copy)]
struct Ev {
    time: f64,
    seq: u64,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl SchedEvent for Ev {
    fn time_ms(&self) -> f64 {
        self.time
    }
}

const N: usize = 1_000_000;

fn main() {
    let mut b = Bench::new("sched");

    // One fixed event stream for every cell: uniform times over a 500 s
    // horizon, pushed in arrival order.
    let mut rng = Rng::new(0x5C4ED);
    let stream: Vec<Ev> =
        (0..N).map(|i| Ev { time: rng.range_f64(0.0, 500_000.0), seq: i as u64 }).collect();

    for kind in [SchedulerKind::Heap, SchedulerKind::Wheel] {
        let mut q = EventQueue::new(kind);

        // Hold-then-drain: the queue reaches depth N before the first pop.
        let name = format!("push_pop_1m_hold_{}", kind.label());
        b.run(&name, || {
            q.clear();
            for ev in &stream {
                q.push(*ev);
            }
            let mut popped = 0usize;
            let mut last = f64::NEG_INFINITY;
            while let Some(ev) = q.pop() {
                assert!(ev.time >= last, "pop order regressed");
                last = ev.time;
                popped += 1;
            }
            popped
        });

        // Steady-state churn: depth ~1k, every pop schedules a successor
        // a short jittered delay ahead — the DES engines' access pattern.
        let name = format!("push_pop_1m_churn_{}", kind.label());
        b.run(&name, || {
            q.clear();
            let mut seq = 0u64;
            for ev in stream.iter().take(1_000) {
                q.push(*ev);
                seq += 1;
            }
            let mut jit = Rng::new(0xC0FFEE);
            let mut popped = 0usize;
            while popped < N {
                let ev = q.pop().expect("queue drained early");
                popped += 1;
                if popped + q.len() < N {
                    q.push(Ev { time: ev.time + jit.range_f64(0.1, 50.0), seq });
                    seq += 1;
                }
            }
            popped
        });
    }

    // Adaptive granularity on the churn regime: the wheel re-fits its
    // bucket width from the inter-event gap EMA at every rebase instead
    // of spanning the batch — the `[perf] wheel_granularity = "auto"`
    // cost row, to be read against `push_pop_1m_churn_wheel` above.
    let mut q = EventQueue::new(SchedulerKind::Wheel);
    q.set_granularity(WheelGranularity::Auto);
    b.run("push_pop_1m_churn_wheel_auto", || {
        q.clear();
        let mut seq = 0u64;
        for ev in stream.iter().take(1_000) {
            q.push(*ev);
            seq += 1;
        }
        let mut jit = Rng::new(0xC0FFEE);
        let mut popped = 0usize;
        while popped < N {
            let ev = q.pop().expect("queue drained early");
            popped += 1;
            if popped + q.len() < N {
                q.push(Ev { time: ev.time + jit.range_f64(0.1, 50.0), seq });
                seq += 1;
            }
        }
        popped
    });

    b.save();
}
