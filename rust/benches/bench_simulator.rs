//! Simulator throughput benches: the synchronous-round environment is the
//! RL training substrate — Table 11's 10^5-10^6 step budgets are only
//! practical if env.step() stays in the microsecond range.

use eeco::agent::Agent;
use eeco::prelude::*;
use eeco::sim::{Env, ResponseModel};
use eeco::util::bench::Bench;

fn main() {
    let mut b = Bench::new("simulator");

    for users in [1usize, 3, 5] {
        let mut env = Env::new(
            Scenario::exp_b(users),
            Calibration::default(),
            AccuracyConstraint::AtLeast(85.0),
            1,
        );
        let d = Decision(
            (0..users).map(|i| Action::from_index((i * 7) % ACTIONS_PER_DEVICE)).collect(),
        );
        b.run(&format!("env_step_n{users}"), || env.step(&d).avg_ms);
        b.run(&format!("expected_avg_n{users}"), || env.expected_avg_ms(&d));
    }

    // response model microkernel
    let net = eeco::network::Network::new(Scenario::exp_a(5), Calibration::default());
    let rm = ResponseModel::new(net);
    let sys = eeco::monitor::SystemState {
        edge: eeco::monitor::NodeState::idle(NetCond::Regular),
        cloud: eeco::monitor::NodeState::idle(NetCond::Regular),
        devices: vec![eeco::monitor::NodeState::idle(NetCond::Regular); 5],
    };
    let ctx = eeco::sim::RoundCtx {
        edge_counts: vec![2],
        cloud_count: 1,
        ingress_counts: vec![3],
    };
    b.run("device_response_ms", || {
        rm.device_response_ms(0, ModelId(4), Tier::Edge(0), &ctx, &sys)
    });

    // full training loop throughput (the Fig 6 inner loop)
    let mut env = Env::new(Scenario::exp_a(3), Calibration::default(), AccuracyConstraint::Max, 2);
    let mut agent = eeco::agent::qlearning::QTableAgent::new(
        3,
        Hyper::paper_defaults(Algo::QLearning, 3),
        eeco::agent::ActionSet::full(),
        3,
    );
    b.run("train_round_ql_n3", || {
        let s = env.encoded();
        let d = agent.decide(&s, true);
        let out = env.step(&d);
        let s2 = env.encoded();
        agent.learn(&s, &d, out.reward, &s2);
    });

    // Training rounds/second through the orchestrator at the paper's
    // 5-user scale (Table 11's budget driver): each iteration is 100
    // cached rounds of decide + step + learn, so rounds/sec is
    // 100 / (mean seconds per iteration). This is the loop the
    // allocation-free sync path + threaded state encoding speed up.
    let env5 =
        Env::new(Scenario::exp_a(5), Calibration::default(), AccuracyConstraint::AtLeast(85.0), 4);
    let agent5 = Box::new(eeco::agent::qlearning::QTableAgent::new(
        5,
        Hyper::paper_defaults(Algo::QLearning, 5),
        eeco::agent::ActionSet::full(),
        5,
    ));
    let mut orch = eeco::orchestrator::Orchestrator::new(env5, agent5);
    b.run("train_100rounds_ql_n5", || orch.train_full(100, 100).steps);

    b.save();
}
